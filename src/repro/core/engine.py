"""CountingEngine: batched multi-coloring, multi-template color-coding runs.

The estimator loop in early revisions dispatched ONE jit call per coloring —
re-entering Python, re-shipping split tables, and syncing a scalar back to
the host every iteration.  This module amortizes all static work across the
whole (epsilon, delta) estimation run, the way the paper's Algorithm 5
amortizes the neighbor reduction across color sets:

* **Plans and tables once** — ``CountingPlan``s are built per template and
  their split tables land on the device a single time, de-duplicated by
  ``(k, m, m_a)``.
* **Backend interface** — each execution strategy is an
  :class:`EngineBackend`: device-operand construction, the fused
  SpMM+eMA stage (:meth:`EngineBackend.aggregate_ema`), and the
  per-coloring live-memory model all live behind one interface.  The local
  backends (``edges`` / ``ell`` / ``sell`` / ``dense`` / ``blocked`` /
  ``custom``) run the fused DP on one device; :class:`MeshBackend`
  (``mesh``) runs the same DP under ``shard_map`` across a device mesh,
  where each column-batched all-gather feeds the fused step per batch
  (:mod:`repro.core.distributed`).
* **Fused execution model** — no backend ever materializes the full
  aggregate product ``A_G @ M_p``: every stage streams the passive state in
  ``column_batch``-column slices, aggregates just that slice, and consumes
  it immediately in the eMA FMA (fp32 accumulation).  DP states are freed
  at their liveness-scheduled last read, so the resident footprint matches
  Algorithm 5's in-place storage.
* **Backend auto-selection** — the local SpMM primitive is picked from
  graph statistics (:func:`select_backend`): edge-list segment-sum for
  small skewed graphs, scatter-free degree-bucketed SELL gathers for large
  skewed graphs (XLA:CPU scatter collapses there), padded ELL for flat
  degree distributions, dense adjacency when the matmul work is
  competitive, and the fused Pallas blocked-ELL kernel for large graphs on
  TPU.  ``REPRO_ENGINE_BACKEND`` overrides the pick; the choice and its
  predicted transient are logged at construction.  Passing ``mesh=``
  selects the ``mesh`` backend.
* **Batched colorings** — a chunk of ``B`` colorings is fused into the
  *column* dimension of the DP state: every M matrix is ``(n, B, C)`` and
  each stage's SpMM is ONE wide neighbor reduction over ``B * C`` columns
  (``lax.map`` walks the chunks inside a single jit).  This is the paper's
  "batch more columns into one SpMM" principle applied across colorings —
  a plain ``vmap`` over the leading axis lowers to batched scatters that
  XLA:CPU executes far slower than one wide scatter.  On the mesh backend
  the same fusion means every all-gather collective serves all ``B``
  colorings at once.
* **Chunk-size picker** — the live M-matrix footprint per coloring is
  derived from the backend's memory model (resident M columns plus the
  per-stage gather transient — for the mesh backend, the per-shard gather
  scratch and the all-gather buffer) and the chunk size is chosen to keep
  ``chunk * footprint`` under a configurable VMEM/HBM budget.
* **Multi-template sharing** — several same-``k`` templates are counted per
  coloring; sub-template DP states and SpMM products are memoized by the
  rooted canonical form (AHU string) of the sub-template, so coinciding
  passive sub-templates (and the leaf one-hot + its neighbor sum, shared by
  *every* template) are computed once per coloring.
* **Dtype policy** — fp32 end-to-end, or bf16 storage/gather traffic with
  fp32 accumulation (paper §VI bf16 discussion).  On the mesh backend the
  storage dtype is also the all-gather wire dtype (plus an optional
  ``gather_dtype`` override for compressed collectives).
"""

from __future__ import annotations

import logging
import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from .colorsets import binom, bucketed_split_entries, colorful_probability
from .counting import (
    CountingPlan,
    build_counting_plan,
    fused_aggregate_ema_grouped,
    liveness_peak_columns,
    schedule_liveness,
)
from .graph import Graph, build_sell
from .templates import Template, partition_template, sub_template_canonical

__all__ = [
    "DtypePolicy",
    "EstimateResult",
    "CountingEngine",
    "EngineBackend",
    "StageTables",
    "select_backend",
    "pick_chunk_size",
    "sub_template_canonical",
    "template_set_canons",
    "engine_cache_key",
    "ENGINE_BACKENDS",
    "DEFAULT_MEMORY_BUDGET_BYTES",
    "MAX_CHUNK_SIZE",
    "BACKEND_ENV_VAR",
]

logger = logging.getLogger("repro.engine")

#: Default live-footprint budget for one chunk of colorings (bytes).  Sized
#: for the CPU/laptop case; on real TPUs pass the per-core VMEM/HBM figure.
DEFAULT_MEMORY_BUDGET_BYTES = 32 * 1024 * 1024

#: Hard cap on colorings fused into one chunk (diminishing returns beyond).
MAX_CHUNK_SIZE = 64

#: Graphs at or below this vertex count use the dense-adjacency backend.
DENSE_MAX_VERTICES = 256

#: ELL is chosen only when padding waste is bounded: ``n * max_deg`` must not
#: exceed this factor times the true directed edge count.
ELL_PAD_FACTOR = 1.5

#: On TPU, graphs at least this large route to the Pallas blocked-ELL kernel.
BLOCKED_MIN_VERTICES = 4096

#: Environment variable overriding the auto-selected local backend.
BACKEND_ENV_VAR = "REPRO_ENGINE_BACKEND"

#: Default passive columns per fused SpMM+eMA slice on the local backends.
#: Empirically (2-core XLA:CPU interleaved A/B on the rmat2k bench graphs):
#: 16 beats both narrower slices (the per-call segment-sum fixed cost is
#: paid more often) and the full-width two-pass dataflow (whose edge-wide
#: transient thrashes cache), while keeping the chunk picker's fused
#: transient small enough to grow coloring chunks 2-4x over the seed.
LOCAL_COLUMN_BATCH = 16

#: Above this ``n * |E_directed|`` product, skewed graphs route to the
#: scatter-free SELL backend: XLA:CPU's scatter lowering falls off a cliff
#: in this regime (observed ~200x on 8k vertices / 130k directed edges)
#: while degree-bucketed gathers stay on the |E|-proportional cost curve.
SELL_MIN_SCATTER_WORK = 5 * 10**8

#: Degree-sorted rows per SELL group (smaller = tighter padding).
SELL_GROUP_SIZE = 128

#: Dense adjacency wins only when the gather path's per-column element work
#: (``|E|``) is within this factor of the dense matmul's per-column ``n^2``
#: MACs — the throughput advantage of regular matmuls over irregular
#: gathers.  (The column count cancels: both paths scale linearly in it.)
DENSE_WORK_ADVANTAGE = 16


@dataclass(frozen=True)
class DtypePolicy:
    """Storage vs accumulation dtypes for the DP state.

    ``store_dtype`` is what M matrices (and therefore the SpMM gather
    traffic — on the mesh backend, also the all-gather wire payload) are
    kept in; ``accum_dtype`` is what neighbor reductions and eMA FMAs
    accumulate in.  ``fp32`` keeps both at float32; ``bf16`` halves the
    storage/gather bytes while accumulating in float32 (paper §VI).
    """

    store_dtype: jnp.dtype
    accum_dtype: jnp.dtype

    @staticmethod
    def resolve(policy: Union[str, "DtypePolicy", jnp.dtype, None]) -> "DtypePolicy":
        """Coerce ``"fp32"`` | ``"bf16"`` | a dtype | a policy | None."""
        if policy is None:
            return DtypePolicy(jnp.float32, jnp.float32)
        if isinstance(policy, DtypePolicy):
            return policy
        if isinstance(policy, str):
            if policy in ("fp32", "float32"):
                return DtypePolicy(jnp.float32, jnp.float32)
            if policy in ("bf16", "bfloat16"):
                return DtypePolicy(jnp.bfloat16, jnp.float32)
            raise ValueError(f"unknown dtype policy {policy!r} (fp32 | bf16)")
        dt = jnp.dtype(policy)
        accum = jnp.float32 if dt in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)) else dt
        return DtypePolicy(dt, accum)


@dataclass
class EstimateResult:
    """Per-template estimation summary (kept API-compatible with the old
    ``estimator.EstimateResult``)."""

    mean: float
    std: float
    per_iteration: np.ndarray
    iterations: int


def select_backend(
    graph: Graph, platform: Optional[str] = None, explain: bool = False
):
    """Pick the local SpMM backend from graph statistics.

    * env override — ``REPRO_ENGINE_BACKEND=<name>`` forces any local
      backend (a bad auto-pick used to be silent and undiagnosable).
    * ``dense``   — tiny graphs, or work-dense graphs where the gather
      path's per-column element work ``|E|`` reaches
      ``n^2 / DENSE_WORK_ADVANTAGE`` (avg degree ``>= n / 16``): one
      (n, n) matmul beats gather/scatter.  The DP column count cancels
      from the comparison — both paths scale linearly in it.
    * ``blocked`` — large graphs on TPU: the fused Pallas blocked-ELL
      SpMM+eMA kernel.
    * ``ell``     — flat degree distributions where row padding is cheap.
    * ``sell``    — rmat8k-class graphs (``n * |E|`` beyond
      ``SELL_MIN_SCATTER_WORK``): scatter-free degree-bucketed gathers;
      XLA:CPU's scatter collapses in this regime.
    * ``edges``   — everything else (small skewed / power-law graphs: a hub
      row would blow the ELL padding up to ``n * max_deg``).

    The ``mesh`` backend is never auto-selected from graph statistics — it
    is chosen by passing ``mesh=`` to :class:`CountingEngine`.

    The decision and its reason are logged on the module logger
    (``repro.engine``, DEBUG) so callers capture it with standard logging
    config; ``explain=True`` additionally returns ``(name, reason)`` for
    structured consumers (:meth:`CountingEngine.describe`).
    """
    name, reason = _select_backend_reason(graph, platform)
    logger.debug(
        "select_backend: %s for n=%d edges=%d (%s)",
        name,
        graph.n,
        graph.num_directed,
        reason,
    )
    return (name, reason) if explain else name


def _select_backend_reason(graph: Graph, platform: Optional[str]) -> Tuple[str, str]:
    env = os.environ.get(BACKEND_ENV_VAR, "").strip()
    if env:
        if env not in ("edges", "ell", "sell", "dense", "blocked"):
            raise ValueError(
                f"{BACKEND_ENV_VAR}={env!r} is not a local backend "
                "(edges | ell | sell | dense | blocked)"
            )
        return env, f"{BACKEND_ENV_VAR} env override"
    platform = platform or jax.default_backend()
    if graph.n <= DENSE_MAX_VERTICES:
        return "dense", f"n={graph.n} <= {DENSE_MAX_VERTICES} (tiny graph)"
    if platform == "tpu" and graph.n >= BLOCKED_MIN_VERTICES:
        return "blocked", f"tpu and n={graph.n} >= {BLOCKED_MIN_VERTICES}"
    edges = max(graph.num_directed, 1)
    if DENSE_WORK_ADVANTAGE * edges >= graph.n**2:
        return "dense", (
            f"{DENSE_WORK_ADVANTAGE}*|E|={DENSE_WORK_ADVANTAGE * edges} >= "
            f"n^2={graph.n**2} (work-dense graph)"
        )
    max_deg = graph.max_degree()
    if graph.n * max_deg <= ELL_PAD_FACTOR * edges:
        return "ell", (
            f"n*max_deg={graph.n * max_deg} <= {ELL_PAD_FACTOR}*|E| "
            "(flat degrees, padding bounded)"
        )
    if graph.n * edges >= SELL_MIN_SCATTER_WORK:
        return "sell", (
            f"n*|E|={graph.n * edges} >= {SELL_MIN_SCATTER_WORK} "
            "(XLA:CPU scatter-cliff regime)"
        )
    return "edges", "skewed degrees below the scatter-cliff regime"


def pick_chunk_size(
    bytes_per_coloring: int,
    memory_budget_bytes: int,
    max_chunk: int = MAX_CHUNK_SIZE,
) -> int:
    """Largest chunk whose live footprint stays under the budget (>= 1)."""
    if bytes_per_coloring <= 0:
        return max_chunk
    return max(1, min(max_chunk, int(memory_budget_bytes // bytes_per_coloring)))


def template_set_canons(
    templates: Sequence[Template],
) -> Tuple[Tuple[str, ...], ...]:
    """Per-template tuple of rooted canonical forms of the DP stages.

    This is the template half of the engine cache key: two template sets
    with equal canon tuples produce identical DP schedules (same stages,
    same split tables, same sharing), so a compiled engine built for one
    serves the other.  Computable without building plans or split tables.
    """
    return tuple(
        tuple(
            sub_template_canonical(t, sub.vertices, sub.root)
            for sub in partition_template(t).subs
        )
        for t in templates
    )


def _assemble_cache_key(
    signature: str,
    canons: Tuple[Tuple[str, ...], ...],
    backend: str,
    policy: "DtypePolicy",
    chunk_spec: Tuple,
    column_batch: Optional[int],
) -> Tuple:
    """The one place the cache-key tuple is laid out — shared by
    :func:`engine_cache_key` (pre-construction) and
    :meth:`CountingEngine.cache_key` (resolved values) so the two
    identities cannot drift."""
    return (
        "counting-engine",
        signature,
        canons,
        backend,
        str(jnp.dtype(policy.store_dtype)),
        str(jnp.dtype(policy.accum_dtype)),
        chunk_spec,
        None if column_batch is None else int(column_batch),
    )


def engine_cache_key(
    graph: Graph,
    templates: Sequence[Template],
    *,
    backend: str = "auto",
    dtype_policy: Union[str, "DtypePolicy", jnp.dtype, None] = "fp32",
    chunk_size: Optional[int] = None,
    memory_budget_bytes: int = DEFAULT_MEMORY_BUDGET_BYTES,
    column_batch: Optional[int] = None,
) -> Tuple:
    """Hashable identity of a compiled :class:`CountingEngine`.

    Two constructions with equal keys trace and compile to the same
    programs, so a cache (``repro.serve.cache.EngineCache``) can hand back
    the warm engine and skip tracing entirely.  Anatomy::

        ("counting-engine",
         graph signature,           # content hash of (n, src, dst)
         template-set canons,       # DP-schedule identity, label-free
         resolved backend name,     # auto-resolution folded in
         store dtype, accum dtype,  # dtype policy
         chunk spec,                # explicit chunk, or the budget that
                                    # deterministically picks one
         column_batch)              # fused-slice width override (or None)

    The key is computable without constructing the engine (plans, tables,
    and device operands are only built on a cache miss).
    """
    return _assemble_cache_key(
        graph.signature(),
        template_set_canons(templates),
        select_backend(graph) if backend == "auto" else backend,
        DtypePolicy.resolve(dtype_policy),
        ("chunk", int(chunk_size)) if chunk_size else ("budget", int(memory_budget_bytes)),
        column_batch,
    )


# ---------------------------------------------------------------------------
# Backend interface
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StageTables:
    """Split tables for one DP stage, in both shapes the fused pipeline needs.

    ``idx_a_host`` / ``idx_p_host`` are the plain ``(n_out, n_splits)`` rank
    tables, kept host-side: the fused Pallas kernel expands them per
    coloring chunk at trace time (``spmm_ema_batched``).  ``batches`` are
    the same entries re-bucketed by passive-column batch and shipped to the
    device (:func:`repro.core.colorsets.bucketed_split_entries`) for the
    streamed pure-JAX executor.  De-duplicated across stages by
    ``(k, m, m_a)``.
    """

    n_out: int
    column_batch: int
    idx_a_host: np.ndarray
    idx_p_host: np.ndarray
    batches: Tuple[Tuple[int, int, jnp.ndarray, jnp.ndarray, jnp.ndarray], ...]


class EngineBackend:
    """One fused SpMM+eMA execution strategy behind :class:`CountingEngine`.

    A backend owns three things:

    * **operand construction** — its device-resident graph representation,
      built once in ``__init__`` (edge lists, ELL/SELL tables, dense
      adjacency, Pallas blocked operands, or the sharded edge partition +
      collective schedule for the mesh backend);
    * **the DP execution** — :meth:`counts_for_colors` maps a ``(B, n)``
      chunk of colorings to ``(B, T)`` raw colorful totals.  The per-stage
      primitive is :meth:`aggregate_ema`: ONE fused neighbor-aggregate +
      eMA step that never materializes the full ``A_G @ M_p`` product
      (local backends stream passive column batches through
      :func:`repro.core.counting.fused_aggregate_ema`; the mesh backend
      runs the equivalent fusion inside its shard_map program, each
      all-gathered column batch feeding the eMA immediately);
    * **the memory model** — :meth:`transient_elements` /
      :meth:`resident_elements` feed the engine's memory-budget chunk
      picker.
    """

    name: str = "abstract"

    def __init__(self, engine: "CountingEngine"):
        self.engine = engine

    # -- execution ----------------------------------------------------------

    def aggregate_ema(
        self, m_p: jnp.ndarray, m_a: jnp.ndarray, tables: StageTables
    ) -> jnp.ndarray:
        """Fused per-stage step: ``(n, B, C_p), (n, B, C_a) -> (n, B, n_out)``
        in accum dtype, without materializing ``A_G @ M_p``."""
        raise NotImplementedError

    def aggregate_ema_grouped(
        self, m_p: jnp.ndarray, stage_inputs: Sequence[Tuple[jnp.ndarray, StageTables]]
    ) -> List[jnp.ndarray]:
        """Run several stages that share the passive state ``m_p``.

        Backends that can share the neighbor aggregation across the group
        override this (the streamed local pipeline computes each passive
        column-batch aggregate once for the whole group); the default is
        the unshared per-stage loop.
        """
        return [self.aggregate_ema(m_p, m_a, tables) for m_a, tables in stage_inputs]

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        """``(B, n)`` colorings -> ``(B, T)`` un-normalized colorful totals."""
        raise NotImplementedError

    def counts_for_keys_chunk(self, keys_chunk: jnp.ndarray) -> jnp.ndarray:
        """``(B, 2)`` PRNG keys -> ``(B, T)`` normalized estimates.

        The coloring draw is identical across backends (one ``randint`` per
        key over the *original* vertex ids), so the same keys produce the
        same colorings — and therefore fp-tolerance-comparable estimates —
        on every backend, mesh included.
        """
        eng = self.engine
        colors = jax.vmap(
            lambda key: jax.random.randint(key, (eng.graph.n,), 0, eng.k)
        )(keys_chunk)
        return self.counts_for_colors(colors) * eng._norm_factors[None, :]

    def make_run_fn(self) -> Callable:
        """One jit for the whole run: ``lax.map`` over key chunks.

        Tracing bumps the engine's ``trace_count`` (a Python side effect
        runs once per trace, i.e. per new compilation), so tests and the
        serving cache can assert that a warm engine never re-compiles.
        """
        engine = self.engine

        def run(keys):
            engine.trace_count += 1
            return jax.lax.map(self.counts_for_keys_chunk, keys)

        return jax.jit(run)

    # -- memory model --------------------------------------------------------

    def transient_elements(self) -> int:
        """Widest per-stage scratch one coloring needs, in store-dtype
        elements (gather intermediates, collective buffers)."""
        raise NotImplementedError

    def resident_elements(self) -> int:
        """Live M-matrix elements one coloring keeps resident."""
        return self.engine.graph.n * self.engine.peak_columns()

    def bytes_per_coloring(self) -> int:
        """Estimated live bytes one coloring contributes to a chunk."""
        itemsize = jnp.dtype(self.engine.policy.store_dtype).itemsize
        return (self.transient_elements() + self.resident_elements()) * itemsize


class LocalBackend(EngineBackend):
    """Shared single-device fused DP: subclasses only supply :meth:`spmm`.

    The multi-template DP walks every plan's stages with DP states memoized
    by rooted canonical form, all M matrices in the fused ``(n, B, C)``
    layout.  Each stage runs through the shared streamed
    :meth:`aggregate_ema` (passive column batches aggregated and consumed
    one at a time), and states are dropped at their liveness-scheduled last
    read — the aggregate product ``A_G @ M_p`` never exists.
    """

    def spmm(self, m: jnp.ndarray) -> jnp.ndarray:
        """One neighbor reduction over a fused ``(n, B, c)`` column slice
        (the fused pipeline only ever passes ``column_batch``-wide slices);
        returns accum dtype."""
        raise NotImplementedError

    def _spmm_counted(self, m: jnp.ndarray) -> jnp.ndarray:
        # the Python-level counter runs once per traced aggregation launch
        self.engine.counters["passive_aggregations"] += 1
        return self.spmm(m)

    def aggregate_ema(self, m_p, m_a, tables: StageTables):
        return self.aggregate_ema_grouped(m_p, [(m_a, tables)])[0]

    def aggregate_ema_grouped(self, m_p, stage_inputs):
        pol = self.engine.policy
        return fused_aggregate_ema_grouped(
            m_p,
            [(m_a, tables.batches, tables.n_out) for m_a, tables in stage_inputs],
            self._spmm_counted,
            pol.accum_dtype,
        )

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        """(B, n) colorings -> (B, T) un-normalized colorful totals.

        Sub-template states are memoized by canonical form, so templates
        sharing passive sub-templates (and every template's leaf stage)
        reuse one state per coloring, and freed at their last scheduled
        read (Algorithm 5's in-place storage).  Stages reading the same
        passive canonical form are executed as one group
        (:attr:`CountingEngine._exec_groups`): the group's passive
        column-batch sweep aggregates each slice once for all of them.
        """
        eng = self.engine
        pol = eng.policy
        leaf = jax.nn.one_hot(colors.T, eng.k, dtype=pol.store_dtype)  # (n, B, k)
        free_at = eng._free_at
        slots: Dict[str, jnp.ndarray] = {}
        totals = []
        executed = set()
        pos = 0
        for p_idx, plan in enumerate(eng.plans):
            canons = eng._canons[p_idx]
            for i, sub in enumerate(plan.partition.subs):
                key = canons[i]
                if key in executed:
                    continue
                executed.add(key)
                if sub.is_leaf:
                    slots[key] = leaf
                elif key not in slots:
                    # group leader: execute every stage sharing this passive
                    # canon over one column-batch sweep (members whose active
                    # state is already live; singleton group otherwise)
                    members = eng._exec_groups[(p_idx, i)]
                    stage_inputs = []
                    for q, j in members:
                        sub_m = eng.plans[q].partition.subs[j]
                        stage_inputs.append(
                            (
                                slots[eng._canons[q][sub_m.active]],
                                eng._stage_tables[(q, j)],
                            )
                        )
                    outs = self.aggregate_ema_grouped(
                        slots[canons[sub.passive]], stage_inputs
                    )
                    for (q, j), m_s in zip(members, outs):
                        slots[eng._canons[q][j]] = m_s.astype(pol.store_dtype)
                # else: already produced early as a member of a prior group
                for dead in free_at.get(pos, ()):
                    slots.pop(dead, None)
                pos += 1
            root = slots[canons[plan.partition.root_index]].astype(pol.accum_dtype)
            # reduce color sets first, then vertices: the per-coloring order
            # is independent of the batch size (bit-exact across chunkings)
            totals.append(root.sum(axis=2).sum(axis=0).astype(jnp.float32))
            for dead in free_at.get(pos, ()):
                slots.pop(dead, None)
            pos += 1
        return jnp.stack(totals, axis=1)  # (B, T)

    def transient_elements(self) -> int:
        # default: one aggregated column-batch slice (n, column_batch)
        return self.engine.graph.n * self.engine.column_batch


class EdgesBackend(LocalBackend):
    """Edge-list gather + segment-sum (the skew-robust default)."""

    name = "edges"

    def __init__(self, engine: "CountingEngine"):
        super().__init__(engine)
        g = engine.graph
        self._src = jnp.asarray(g.src)
        self._dst = jnp.asarray(g.dst)

    def spmm(self, m):
        return jax.ops.segment_sum(
            m[self._src].astype(self.engine.policy.accum_dtype),
            self._dst,
            num_segments=self.engine.graph.n,
            indices_are_sorted=True,
        )

    def transient_elements(self) -> int:
        # per batch: the (edges, column_batch) message gather + its
        # aggregated (n, column_batch) slice
        eng = self.engine
        return (eng.graph.num_directed + eng.graph.n) * eng.column_batch


class EllBackend(LocalBackend):
    """Padded-row neighbor gather (flat degree distributions)."""

    name = "ell"

    def __init__(self, engine: "CountingEngine"):
        super().__init__(engine)
        nbr, mask = engine.graph.ell()
        self._nbr = jnp.asarray(nbr)
        self._ell_mask = jnp.asarray(mask)

    def spmm(self, m):
        pol = self.engine.policy
        gathered = m[self._nbr].astype(pol.accum_dtype)  # (n, max_deg, B, c)
        return jnp.einsum("ndbc,nd->nbc", gathered, self._ell_mask.astype(pol.accum_dtype))

    def transient_elements(self) -> int:
        g = self.engine.graph
        return (g.n * max(g.max_degree(), 1) + g.n) * self.engine.column_batch


class SellBackend(LocalBackend):
    """Degree-bucketed sliced-ELL gather — scatter-free (rmat8k-class graphs).

    Vertices are degree-sorted into :data:`SELL_GROUP_SIZE`-row groups,
    each padded only to its own max degree (:func:`repro.core.graph.
    build_sell`); the neighbor reduction is a padded row gather + masked
    einsum per group, stitched back through one inverse-permutation gather.
    No scatter appears anywhere — this sidesteps the XLA:CPU scatter cliff
    that made the edge-list ``segment_sum`` 5–10x *slower* than the scalar
    traversal baseline on rmat8k, while keeping padding bounded on
    power-law degree distributions (unlike plain ELL).
    """

    name = "sell"

    def __init__(self, engine: "CountingEngine", group_size: int = SELL_GROUP_SIZE):
        super().__init__(engine)
        sell = build_sell(engine.graph, group_size=group_size)
        self._sell_padded_slots = sell.padded_slots
        self._groups = tuple(
            (jnp.asarray(nbr), jnp.asarray(mask))
            for nbr, mask in zip(sell.group_nbr, sell.group_mask)
        )
        self._inv_order = jnp.asarray(sell.inv_order)

    def spmm(self, m):
        pol = self.engine.policy
        parts = [
            jnp.einsum(
                "rdbc,rd->rbc",
                m[nbr].astype(pol.accum_dtype),
                mask.astype(pol.accum_dtype),
            )
            for nbr, mask in self._groups
        ]
        return jnp.concatenate(parts, axis=0)[self._inv_order]

    def transient_elements(self) -> int:
        # per batch: the padded group gathers + the aggregated slice
        eng = self.engine
        return (self._sell_padded_slots + eng.graph.n) * eng.column_batch


class DenseBackend(LocalBackend):
    """Dense-adjacency matmul (tiny graphs)."""

    name = "dense"

    def __init__(self, engine: "CountingEngine"):
        super().__init__(engine)
        self._adj = jnp.asarray(engine.graph.dense_adjacency())

    def spmm(self, m):
        pol = self.engine.policy
        n, b, c = m.shape
        out = jnp.matmul(
            self._adj.astype(pol.store_dtype),
            m.reshape(n, b * c),
            preferred_element_type=pol.accum_dtype,
        )
        return out.reshape(n, b, c).astype(pol.accum_dtype)


class BlockedEllBackend(LocalBackend):
    """Fused Pallas SpMM+eMA kernel over blocked-ELL (large graphs on TPU).

    Each stage is ONE :func:`repro.kernels.spmm_ema.ops.spmm_ema` call: per
    destination vertex block the kernel accumulates that block's aggregate
    columns in VMEM scratch and consumes them in the eMA FMA against the
    resident ``M_a`` tile the moment the block's last edge pair lands —
    the aggregate product never reaches HBM (this subsumed the removed
    standalone ``repro.kernels.ema`` kernel, which fused only the eMA half).
    """

    name = "blocked"

    def __init__(self, engine: "CountingEngine", block_size: int = 256):
        super().__init__(engine)
        from repro.kernels.spmm_ema.ops import prepare_fused_operand

        self._fused_op = prepare_fused_operand(engine.graph, block_size=block_size)

    def spmm(self, m):
        # kernel is 2-D (n, C) — fuse batch into columns
        from repro.kernels.spmm_blocked.ops import spmm_blocked

        n, b, c = m.shape
        out = spmm_blocked(
            self._fused_op.blocked,
            m.reshape(n, b * c).astype(jnp.float32),
            interpret=self.engine.interpret,
        )
        return out.reshape(n, b, c).astype(self.engine.policy.accum_dtype)

    def aggregate_ema(self, m_p, m_a, tables: StageTables):
        from repro.kernels.spmm_ema.ops import spmm_ema_batched

        self.engine.counters["passive_aggregations"] += 1
        return spmm_ema_batched(
            self._fused_op,
            m_p,
            m_a,
            tables.idx_a_host,
            tables.idx_p_host,
            interpret=self.engine.interpret,
        ).astype(self.engine.policy.accum_dtype)

    def aggregate_ema_grouped(self, m_p, stage_inputs):
        # the Pallas kernel fuses SpMM+eMA per stage inside one launch; a
        # cross-stage sweep cannot share its VMEM aggregate scratch, so the
        # group degrades to the per-stage loop (counted per launch)
        return [self.aggregate_ema(m_p, m_a, tables) for m_a, tables in stage_inputs]

    def transient_elements(self) -> int:
        # transposed-layout staging of one stage's operands/output; no
        # edge-wide or (n, C_p) aggregate intermediate exists
        eng = self.engine
        return eng.graph.n * eng._max_stage_columns()


class CustomBackend(LocalBackend):
    """Caller-supplied ``(n, C) -> (n, C)`` neighbor-sum kernel."""

    name = "custom"

    def __init__(self, engine: "CountingEngine", spmm_fn: Callable):
        super().__init__(engine)
        self._spmm_fn = spmm_fn

    def spmm(self, m):
        n, b, c = m.shape
        out = self._spmm_fn(m.reshape(n, b * c))
        return out.reshape(n, b, c).astype(self.engine.policy.accum_dtype)

    def transient_elements(self) -> int:
        # assume edge-list-like internals (the conservative choice)
        eng = self.engine
        return (eng.graph.num_directed + eng.graph.n) * eng.column_batch


class MeshBackend(EngineBackend):
    """Distributed backend: the fused DP under ``shard_map`` on a device mesh.

    Wraps the column-batched all-gather SpMM and streamed eMA of
    :mod:`repro.core.distributed`: vertices are 1-D row-partitioned across
    every mesh axis, each DP stage all-gathers the passive M matrix in
    ``column_batch``-column slices (each collective serving all ``B``
    chunked colorings at once), and the eMA stays vertex-local.  Split
    tables are built once per plan at construction, de-duplicated by
    ``(k, m, m_a)``, and closure-captured by the shard_map program.

    Args (via ``CountingEngine(...)``):
      mesh: the ``jax.sharding.Mesh`` to run on (required).
      column_batch: passive columns per all-gather; ``None`` auto-sizes to
        ``min(128, max passive column count)``.
      ema_mode: ``"streamed"`` (default — fused per-batch SpMM->eMA, the B
        matrix never materializes) or ``"loop"`` (paper-faithful Algorithm
        5 with the SpMM product memoized per canonical passive form).
      gather_dtype: optional wire dtype for compressed all-gathers
        (e.g. ``jnp.bfloat16``); accumulation stays fp32.
      balance_degrees: relabel vertices round-robin by degree rank before
        sharding (spreads hub rows; colorings are permuted to follow, so
        counts are unchanged).
    """

    name = "mesh"

    def __init__(
        self,
        engine: "CountingEngine",
        mesh,
        *,
        column_batch: Optional[int] = None,
        ema_mode: str = "streamed",
        gather_dtype=None,
        balance_degrees: bool = False,
    ):
        super().__init__(engine)
        if mesh is None:
            raise ValueError("backend='mesh' needs a jax.sharding.Mesh (mesh=...)")
        from .distributed import make_batched_count_fn, mesh_peak_columns, shard_graph

        self.mesh = mesh
        self.ema_mode = ema_mode
        self.gather_dtype = gather_dtype
        n_shards = int(np.prod(mesh.devices.shape))
        self.sharded = shard_graph(engine.graph, n_shards, balance_degrees=balance_degrees)
        if column_batch is None:
            column_batch = min(128, max(engine._max_passive_columns(), engine.k))
        self.column_batch = int(column_batch)
        self._count_fn = make_batched_count_fn(
            engine.plans,
            mesh,
            self.sharded.n_padded,
            self.sharded.edges_per_shard,
            column_batch=self.column_batch,
            ema_mode=ema_mode,
            gather_dtype=gather_dtype,
            canons=engine._canons,
            store_dtype=engine.policy.store_dtype,
            accum_dtype=engine.policy.accum_dtype,
        )
        self._src = jnp.asarray(self.sharded.src)
        self._dst_local = jnp.asarray(self.sharded.dst_local)
        self._edge_mask = jnp.asarray(self.sharded.edge_mask)
        # colorings follow the degree-balancing relabel (scatter old -> new;
        # new ids range over [0, n_padded) with pad slots interleaved)
        self._perm = (
            jnp.asarray(self.sharded.perm) if self.sharded.perm is not None else None
        )
        self._peak_padded = mesh_peak_columns(
            engine.plans, engine._canons, ema_mode, self.column_batch
        )

    def counts_for_colors(self, colors: jnp.ndarray) -> jnp.ndarray:
        colors = jnp.asarray(colors)
        if self._perm is not None:
            padded = jnp.zeros((colors.shape[0], self.sharded.n_padded), colors.dtype)
            colors = padded.at[:, self._perm].set(colors)
        else:
            pad = self.sharded.n_padded - colors.shape[1]
            if pad:
                colors = jnp.pad(colors, ((0, 0), (0, pad)))
        return self._count_fn(colors, self._src, self._dst_local, self._edge_mask)

    # -- memory model (per shard!) -------------------------------------------

    def transient_elements(self) -> int:
        """Per-shard collective scratch: one all-gathered column batch
        (``n_padded * column_batch``) plus the per-shard edge message gather
        (``edges_per_shard * column_batch``)."""
        cb = self.column_batch
        return self.sharded.n_padded * cb + self.sharded.edges_per_shard * cb

    def resident_elements(self) -> int:
        """Per-shard live DP state: local rows times the liveness-aware
        peak of padded M columns under the shared multi-template schedule."""
        return self.sharded.rows_per_shard * self._peak_padded


ENGINE_BACKENDS = ("edges", "ell", "sell", "dense", "blocked", "mesh", "custom")


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class CountingEngine:
    """Batched color-coding counting runs over one graph.

    Args:
      graph: the network.
      templates: one :class:`Template` or a sequence of same-``k`` templates
        counted together per coloring (shared leaf one-hot / DP states).
      backend: ``auto`` | ``edges`` | ``ell`` | ``sell`` | ``dense`` |
        ``blocked`` | ``mesh``.  ``auto`` resolves from graph statistics
        (:func:`select_backend`, overridable via ``REPRO_ENGINE_BACKEND``),
        or to ``mesh`` when ``mesh=`` is given.  Ignored when ``spmm_fn``
        is given.
      spmm_fn: optional custom ``(n, C) -> (n, C)`` neighbor-sum kernel.
      dtype_policy: ``fp32`` | ``bf16`` | a :class:`DtypePolicy` | a dtype.
      memory_budget_bytes: live-footprint budget steering the chunk picker
        (per device — for the mesh backend the model is per shard).
      chunk_size: explicit colorings-per-chunk override (skips the picker).
      plans: optional pre-built :class:`CountingPlan` per template.
      block_size / interpret: fused Pallas kernel knobs (``blocked``).
      column_batch: passive columns aggregated per fused SpMM+eMA slice.
        ``None`` auto-sizes: ``min(16, max passive columns)`` on the local
        backends, ``min(128, max passive columns)`` on the mesh backend
        (where a batch is also one all-gather collective).
      mesh / ema_mode / gather_dtype / balance_degrees: mesh-backend knobs
        — see :class:`MeshBackend`.
    """

    def __init__(
        self,
        graph: Graph,
        templates: Union[Template, Sequence[Template]],
        *,
        backend: str = "auto",
        spmm_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
        dtype_policy: Union[str, DtypePolicy, jnp.dtype, None] = "fp32",
        memory_budget_bytes: int = DEFAULT_MEMORY_BUDGET_BYTES,
        chunk_size: Optional[int] = None,
        plans: Optional[Sequence[CountingPlan]] = None,
        block_size: int = 256,
        interpret: bool = False,
        mesh=None,
        column_batch: Optional[int] = None,
        ema_mode: str = "streamed",
        gather_dtype=None,
        balance_degrees: bool = False,
    ):
        if isinstance(templates, Template):
            templates = [templates]
        if not templates:
            raise ValueError("CountingEngine needs at least one template")
        ks = {t.k for t in templates}
        if len(ks) != 1:
            raise ValueError(
                f"all templates must share one k to share colorings, got k={sorted(ks)}"
            )
        self.graph = graph
        self.templates: Tuple[Template, ...] = tuple(templates)
        self.k = ks.pop()
        self.policy = DtypePolicy.resolve(dtype_policy)
        self.memory_budget_bytes = int(memory_budget_bytes)
        self.interpret = interpret
        self.mesh = mesh

        if plans is None:
            self.plans: Tuple[CountingPlan, ...] = tuple(
                build_counting_plan(t) for t in self.templates
            )
        else:
            if len(plans) != len(self.templates):
                raise ValueError("plans must align with templates")
            self.plans = tuple(plans)

        # --- static schedule: canonical keys + liveness + device tables.
        self._canons: List[List[str]] = [
            [
                sub_template_canonical(plan.template, sub.vertices, sub.root)
                for sub in plan.partition.subs
            ]
            for plan in self.plans
        ]
        self._free_at = schedule_liveness(self.plans, self._canons)

        # Fused-slice width: local default keeps the per-batch edge gather
        # cache-sized; the mesh backend auto-sizes its own (one batch there
        # is also one all-gather collective).
        if column_batch:
            self.column_batch = int(column_batch)
        else:
            self.column_batch = min(LOCAL_COLUMN_BATCH, self._max_passive_columns())

        norm = colorful_probability(self.k)
        self._norm_factors = jnp.asarray(
            [1.0 / (norm * plan.automorphisms) for plan in self.plans], jnp.float32
        )

        # --- backend resolution (operands built once, below).
        if spmm_fn is not None:
            self.backend = "custom"
            self.backend_source = "custom"
            self.backend_reason = "caller-supplied spmm_fn"
        elif backend == "auto":
            if mesh is not None:
                self.backend = "mesh"
                self.backend_source = "mesh"
                self.backend_reason = "mesh= given"
            else:
                self.backend, self.backend_reason = select_backend(graph, explain=True)
                self.backend_source = (
                    "env"
                    if os.environ.get(BACKEND_ENV_VAR, "").strip()
                    else "auto"
                )
        else:
            self.backend = backend
            self.backend_source = "explicit"
            self.backend_reason = "backend= given"

        # Bucketed per-batch tables feed the local fused executor and the
        # Pallas kernel only; the mesh backend builds its own streamed
        # tables at its own (all-gather) column batch.
        table_cache: Dict[Tuple[int, int, int], StageTables] = {}
        self._stage_tables: Dict[Tuple[int, int], StageTables] = {}
        if self.backend != "mesh":
            for p_idx, plan in enumerate(self.plans):
                for i, table in enumerate(plan.tables):
                    if table is None:
                        continue
                    key = (table.k, table.m, table.m_a)
                    if key not in table_cache:
                        table_cache[key] = StageTables(
                            n_out=table.n_out,
                            column_batch=self.column_batch,
                            idx_a_host=table.idx_a,
                            idx_p_host=table.idx_p,
                            batches=tuple(
                                (
                                    lo,
                                    width,
                                    jnp.asarray(ia),
                                    jnp.asarray(ip),
                                    None if va is None else jnp.asarray(va),
                                )
                                for lo, width, ia, ip, va in bucketed_split_entries(
                                    table, self.column_batch
                                )
                            ),
                        )
                    self._stage_tables[(p_idx, i)] = table_cache[key]

        # Shared-passive execution groups: stages reading one passive canon
        # whose active states are all live before the group's first stage
        # execute together over a single column-batch sweep.
        self._exec_groups = self._build_shared_passive_groups()

        # Observability counters.  ``trace_count`` increments once per jit
        # trace (== compilation) of a run/chunk program; the aggregation
        # counter increments per passive-aggregation launch (the
        # shared-passive satellite's test hook).  Python-level: they count
        # traced work, so a warm engine replaying compiled programs holds
        # steady.
        self.trace_count = 0
        self.counters: Dict[str, int] = {"passive_aggregations": 0}

        self.backend_impl: EngineBackend = self._make_backend(
            spmm_fn=spmm_fn,
            block_size=block_size,
            column_batch=column_batch,
            ema_mode=ema_mode,
            gather_dtype=gather_dtype,
            balance_degrees=balance_degrees,
        )

        # remembered for the cache key: a None chunk means "picked from the
        # budget", which is itself deterministic given the budget
        self._chunk_explicit = bool(chunk_size)
        self._column_batch_arg = column_batch
        self.chunk_size = int(chunk_size) if chunk_size else pick_chunk_size(
            self.bytes_per_coloring(), self.memory_budget_bytes
        )

        self._graph_signature: Optional[str] = None  # computed lazily
        if logger.isEnabledFor(logging.INFO):
            # describe() hashes the graph (O(|E|) host work) — only pay for
            # it when the line is actually emitted; services that want the
            # record call describe() themselves
            d = self.describe()
            logger.info(
                "CountingEngine backend=%s (%s: %s) n=%d edges=%d k=%d templates=%d "
                "column_batch=%d chunk=%d predicted transient=%.2f MiB "
                "resident=%.2f MiB per coloring",
                d["backend"],
                d["backend_source"],
                d["backend_reason"],
                d["n"],
                d["num_directed"],
                d["k"],
                len(self.templates),
                d["column_batch"],
                d["chunk_size"],
                d["memory"]["predicted_transient_bytes"] / 2**20,
                d["memory"]["predicted_resident_bytes"] / 2**20,
            )

        self._run_fn = None  # built lazily (jit cache)
        self._chunk_fn = None  # streaming per-chunk jit (serving path)

    def _make_backend(
        self, *, spmm_fn, block_size, column_batch, ema_mode, gather_dtype, balance_degrees
    ) -> EngineBackend:
        if self.backend == "custom":
            return CustomBackend(self, spmm_fn)
        if self.backend == "edges":
            return EdgesBackend(self)
        if self.backend == "ell":
            return EllBackend(self)
        if self.backend == "sell":
            return SellBackend(self)
        if self.backend == "dense":
            return DenseBackend(self)
        if self.backend == "blocked":
            return BlockedEllBackend(self, block_size=block_size)
        if self.backend == "mesh":
            return MeshBackend(
                self,
                self.mesh,
                column_batch=column_batch,
                ema_mode=ema_mode,
                gather_dtype=gather_dtype,
                balance_degrees=balance_degrees,
            )
        raise ValueError(f"unknown backend {self.backend!r} (one of {ENGINE_BACKENDS})")

    def _build_shared_passive_groups(self) -> Dict[Tuple[int, int], Tuple[Tuple[int, int], ...]]:
        """Static schedule of shared-passive stage groups.

        Walks the first-occurrence stages in execution order; each non-leaf
        stage either leads a group or was claimed by an earlier leader.  A
        later stage joins a leader's group when (a) it reads the same
        passive canonical form and (b) its active state is already computed
        before the leader's position (group members execute at the leader's
        position, so inputs produced between leader and member cannot be
        used).  Pulling a member earlier only moves its reads/writes
        forward, so the sequential liveness schedule (``_free_at``) stays
        valid: nothing a group reads can have been freed yet, and outputs
        are never freed before their sequential last read.

        Returns ``leader (plan_idx, stage_idx) -> members`` (leader first;
        singleton groups for unshared stages).
        """
        seq: List[Tuple[int, int, str]] = []  # first occurrences, exec order
        seen = set()
        for p_idx, plan in enumerate(self.plans):
            for i, _ in enumerate(plan.partition.subs):
                c = self._canons[p_idx][i]
                if c in seen:
                    continue
                seen.add(c)
                seq.append((p_idx, i, c))
        # canons computed strictly before each seq position
        avail_before: List[frozenset] = []
        acc: set = set()
        for _, _, c in seq:
            avail_before.append(frozenset(acc))
            acc.add(c)
        groups: Dict[Tuple[int, int], Tuple[Tuple[int, int], ...]] = {}
        member: set = set()
        for idx, (p_idx, i, _) in enumerate(seq):
            sub = self.plans[p_idx].partition.subs[i]
            if sub.is_leaf or (p_idx, i) in member:
                continue
            passive_canon = self._canons[p_idx][sub.passive]
            members = [(p_idx, i)]
            for jdx in range(idx + 1, len(seq)):
                q, j, _ = seq[jdx]
                sub2 = self.plans[q].partition.subs[j]
                if sub2.is_leaf or (q, j) in member:
                    continue
                if self._canons[q][sub2.passive] != passive_canon:
                    continue
                if self._canons[q][sub2.active] not in avail_before[idx]:
                    continue
                members.append((q, j))
                member.add((q, j))
            groups[(p_idx, i)] = tuple(members)
        return groups

    # ------------------------------------------------------------------
    # Identity & observability (the serving layer builds on these)
    # ------------------------------------------------------------------

    def graph_signature(self) -> str:
        """Content hash of the graph (memoized; see :meth:`Graph.signature`)."""
        if self._graph_signature is None:
            self._graph_signature = self.graph.signature()
        return self._graph_signature

    def cache_key(self) -> Tuple:
        """This engine's :func:`engine_cache_key` (resolved values).

        Matches what a caller computes *before* construction with the same
        arguments, so ``CountingService`` can look up a warm engine without
        building one.  Only meaningful for the named local backends — a
        ``custom`` ``spmm_fn``'s identity is not captured by the key.
        """
        return _assemble_cache_key(
            self.graph_signature(),
            tuple(tuple(c) for c in self._canons),
            self.backend,
            self.policy,
            ("chunk", self.chunk_size)
            if self._chunk_explicit
            else ("budget", self.memory_budget_bytes),
            self._column_batch_arg,
        )

    def describe(self) -> Dict:
        """Structured construction/decision record.

        One dict with everything the construction log line says — the
        backend decision and its reason, shapes, dtype policy, chunk plan,
        and the memory model — so services can attach it to cache entries
        and surface it without parsing log text.
        """
        itemsize = jnp.dtype(self.policy.store_dtype).itemsize
        return {
            "backend": self.backend,
            "backend_source": self.backend_source,
            "backend_reason": self.backend_reason,
            "n": self.graph.n,
            "num_directed": self.graph.num_directed,
            "k": self.k,
            "templates": [t.name for t in self.templates],
            "dtype_policy": {
                "store": str(jnp.dtype(self.policy.store_dtype)),
                "accum": str(jnp.dtype(self.policy.accum_dtype)),
            },
            # the mesh backend aggregates at its own all-gather batch width
            "column_batch": getattr(self.backend_impl, "column_batch", self.column_batch),
            "chunk_size": self.chunk_size,
            "shared_passive_groups": sum(
                1 for m in self._exec_groups.values() if len(m) > 1
            ),
            "memory": {
                "budget_bytes": self.memory_budget_bytes,
                "predicted_transient_bytes": self.backend_impl.transient_elements()
                * itemsize,
                "predicted_resident_bytes": self.backend_impl.resident_elements()
                * itemsize,
                "bytes_per_coloring": self.bytes_per_coloring(),
            },
            "graph_signature": self.graph_signature(),
            "cache_key": self.cache_key(),
        }

    # ------------------------------------------------------------------
    # Memory planning
    # ------------------------------------------------------------------

    def peak_columns(self) -> int:
        """Peak live M columns per coloring across the shared DP.

        Liveness-aware: states shared across templates by canonical form
        are freed at their last scheduled read, and the fused pipeline
        never holds an aggregate product, so the figure is the simulated
        peak of the schedule (for a single template it equals the in-place
        bound ``CountingPlan.peak_columns()``).
        """
        return liveness_peak_columns(self.plans, self._canons)

    def _max_passive_columns(self) -> int:
        cp = 1
        for plan in self.plans:
            for sub in plan.partition.subs:
                if not sub.is_leaf:
                    passive = plan.partition.subs[sub.passive]
                    cp = max(cp, binom(self.k, passive.size))
        return cp

    def _max_stage_columns(self) -> int:
        """Widest single stage: active + passive + output columns (the fused
        Pallas kernel's per-stage transposed staging footprint)."""
        widest = 1
        for plan in self.plans:
            for i, sub in enumerate(plan.partition.subs):
                if sub.is_leaf:
                    continue
                active = plan.partition.subs[sub.active]
                passive = plan.partition.subs[sub.passive]
                widest = max(
                    widest,
                    binom(self.k, active.size)
                    + binom(self.k, passive.size)
                    + binom(self.k, sub.size),
                )
        return widest

    def bytes_per_coloring(self) -> int:
        """Estimated live bytes one coloring contributes to a chunk.

        Delegates to the backend's memory model: resident M-matrix state
        plus the widest per-stage transient (edge/row gather scratch for the
        local backends; all-gather buffer + per-shard message gather for the
        mesh backend, where the figure is per shard).
        """
        return self.backend_impl.bytes_per_coloring()

    def predicted_peak_bytes(self) -> int:
        """The chunk picker's live-footprint prediction for one chunk."""
        return self.chunk_size * self.bytes_per_coloring()

    def compiled_memory_analysis(self, iterations: Optional[int] = None) -> Dict[str, Optional[float]]:
        """Compile one run and compare XLA's measured temp allocation with
        the chunk picker's prediction (the ROADMAP calibration item).

        Returns ``{"predicted_bytes", "actual_temp_bytes", "ratio"}`` with
        ``actual_temp_bytes`` / ``ratio`` ``None`` when the backend does not
        expose ``memory_analysis()`` (it is optional in XLA).
        """
        iters = int(iterations) if iterations else self.chunk_size
        chunk = max(1, min(self.chunk_size, iters))
        n_chunks = -(-iters // chunk)
        keys = jnp.zeros((n_chunks, chunk, 2), jnp.uint32)
        predicted = float(self.predicted_peak_bytes())
        actual: Optional[float] = None
        try:
            compiled = self._get_run_fn().lower(keys).compile()
            analysis = compiled.memory_analysis()
            actual = float(analysis.temp_size_in_bytes)
        except (AttributeError, NotImplementedError, TypeError) as exc:  # pragma: no cover
            logger.info("memory_analysis unavailable on this backend: %s", exc)
        except Exception as exc:  # pragma: no cover - backend-specific failures
            logger.info("memory_analysis failed: %s", exc)
        return {
            "predicted_bytes": predicted,
            "actual_temp_bytes": actual,
            "ratio": (predicted / actual) if actual else None,
        }

    # ------------------------------------------------------------------
    # Public API
    # ------------------------------------------------------------------

    def raw_counts(self, colors) -> jnp.ndarray:
        """(n,) coloring -> (T,) raw colorful totals (test/inspection hook)."""
        colors = jnp.asarray(colors)
        return self.backend_impl.counts_for_colors(colors[None, :])[0]

    def _get_run_fn(self):
        if self._run_fn is None:
            self._run_fn = self.backend_impl.make_run_fn()
        return self._run_fn

    def _get_chunk_fn(self):
        if self._chunk_fn is None:
            impl = self.backend_impl

            def chunk_run(keys):
                self.trace_count += 1
                return impl.counts_for_keys_chunk(keys)

            self._chunk_fn = jax.jit(chunk_run)
        return self._chunk_fn

    def count_keys_chunk(self, keys) -> np.ndarray:
        """Streaming increment: one chunk-shaped launch, results back now.

        The serving path: callers stream iterations through repeated calls
        (adaptive stopping folds each increment into its running estimate)
        instead of fixing N upfront.  ``keys`` is ``(m, 2)`` with
        ``m <= chunk_size``; short increments are padded with the last key
        up to ``chunk_size`` so every call hits ONE compiled shape — a warm
        engine never re-traces, whatever increment sizes arrive
        (shape-bucketed padding).  Returns the ``(m, T)`` normalized
        estimates as a float64 host array.
        """
        keys = jnp.asarray(keys)
        m = int(keys.shape[0])
        if m == 0:
            return np.zeros((0, len(self.templates)), np.float64)
        if m > self.chunk_size:
            raise ValueError(
                f"increment of {m} keys exceeds chunk_size={self.chunk_size}; "
                "split it (count_keys handles multi-chunk runs)"
            )
        pad = self.chunk_size - m
        if pad:
            keys = jnp.concatenate([keys, keys[-1:].repeat(pad, axis=0)], axis=0)
        vals = self._get_chunk_fn()(keys)
        return np.asarray(vals, dtype=np.float64)[:m]

    def count_keys(self, keys) -> np.ndarray:
        """Normalized per-iteration estimates for explicit PRNG keys.

        ``keys``: (iters, 2) uint32 PRNG keys (``jax.random.split`` output).
        Returns an (iters, T) float64 host array; all device work happens in
        one jit call (chunked ``lax.map`` over ``chunk_size``-wide batches).
        """
        keys = jnp.asarray(keys)
        iters = keys.shape[0]
        chunk = max(1, min(self.chunk_size, iters))
        n_chunks = -(-iters // chunk)
        pad = n_chunks * chunk - iters
        if pad:
            keys = jnp.concatenate([keys, keys[-1:].repeat(pad, axis=0)], axis=0)
        vals = self._get_run_fn()(keys.reshape(n_chunks, chunk, *keys.shape[1:]))
        flat = np.asarray(vals, dtype=np.float64).reshape(n_chunks * chunk, -1)
        return flat[:iters]

    def estimate(self, iterations: int = 32, seed: int = 0) -> List[EstimateResult]:
        """Run ``iterations`` random colorings; one :class:`EstimateResult`
        per template (paper Algorithm 1, batched)."""
        keys = jax.random.split(jax.random.PRNGKey(seed), iterations)
        vals = self.count_keys(keys)  # (iters, T)
        return [
            EstimateResult(
                mean=float(vals[:, t].mean()),
                std=float(vals[:, t].std()),
                per_iteration=vals[:, t],
                iterations=iterations,
            )
            for t in range(len(self.templates))
        ]
