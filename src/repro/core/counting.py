"""Color-coding DP: SUBGRAPH2VEC vectorized, traversal reference, brute force.

Three implementations with one contract:

* :func:`count_colorful_vectorized` — the paper's Algorithm 5 (SpMM + eMA) in
  JAX.  Per DP stage, ONE batched neighbor reduction over all passive color
  columns (the SpMM) followed by a vertex-local fused multiply-add over the
  split tables (the eMA).  jit-able; the SpMM implementation is pluggable
  (edge-list segment-sum, ELL gather, dense, or the Pallas blocked kernel).
* :func:`count_colorful_traversal` — Algorithm 2, the FASCIA graph-traversal
  model: the neighbor reduction is re-done for every (output color set,
  split) pair.  NumPy; serves as the correctness reference and the paper's
  performance baseline (its redundancy is exactly what Eq. 1 removes).
* :func:`brute_force_embeddings` / :func:`brute_force_colorful` — exact
  backtracking counts for tiny graphs; anchor the whole chain.

Per coloring, all three agree exactly (up to fp rounding — paper Fig 14).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .colorsets import (
    SplitTable,
    UnionSplitTable,
    binom,
    build_split_table,
    build_union_split_table,
    colorful_probability,
)
from .graph import Graph
from .templates import (
    BagProgram,
    Template,
    TemplatePartition,
    build_bag_program,
    graph_automorphisms,
    partition_template,
    tree_automorphisms,
)

__all__ = [
    "CountingPlan",
    "build_counting_plan",
    "spmm_edges",
    "spmm_ell",
    "fused_aggregate_ema",
    "fused_aggregate_ema_grouped",
    "schedule_liveness",
    "liveness_peak_columns",
    "liveness_peak_elements",
    "count_colorful_vectorized",
    "count_colorful_traversal",
    "brute_force_embeddings",
    "brute_force_colorful",
    "normalize_count",
]


@dataclass(frozen=True)
class CountingPlan:
    """Static DP schedule for one template: stages + split tables.

    Tree templates carry a ``partition`` (binary sub-template recursion,
    paper §II-C) with one optional :class:`SplitTable` per sub-template;
    non-tree templates carry a ``bag_program`` (tree-decomposition lowering)
    with one optional :class:`SplitTable` (extend) or
    :class:`UnionSplitTable` (join) per bag op.  Exactly one of
    ``partition`` / ``bag_program`` is set; executors branch on
    ``partition is not None`` and the tree path is untouched by the bag
    generalization.
    """

    template: Template
    partition: Optional[TemplatePartition]
    k: int
    tables: Tuple[object, ...]  # SplitTable | UnionSplitTable | None per stage
    automorphisms: int
    bag_program: Optional[BagProgram] = None

    @property
    def is_tree_plan(self) -> bool:
        return self.partition is not None

    @property
    def num_subs(self) -> int:
        if self.partition is not None:
            return len(self.partition.subs)
        return len(self.bag_program.ops)

    def stage_canons(self) -> Tuple[str, ...]:
        """Canonical form per stage (sub-template or bag op), in DP order."""
        if self.partition is not None:
            from .templates import sub_template_canonical

            return tuple(
                sub_template_canonical(self.template, sub.vertices, sub.root)
                for sub in self.partition.subs
            )
        return tuple(op.canon for op in self.bag_program.ops)

    def table_arrays(self) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        return {
            i: (t.idx_a, t.idx_p)
            for i, t in enumerate(self.tables)
            if t is not None
        }

    def peak_columns(self) -> int:
        """Max total live M columns — the memory planner's key figure.

        For bag plans this counts colorset columns of live states (the
        per-state vertex-axis factor ``n^len(axes)`` is accounted for by
        :func:`liveness_peak_elements`, which the cost model uses instead).
        """
        if self.partition is not None:
            live: Dict[int, int] = {}
            peak = 0
            for i, sub in enumerate(self.partition.subs):
                live[i] = binom(self.k, sub.size)
                peak = max(peak, sum(live.values()))
                if not sub.is_leaf:
                    live.pop(sub.active, None)
                    live.pop(sub.passive, None)
            return peak
        ops = self.bag_program.ops
        last_read: Dict[int, int] = {}
        for i, op in enumerate(ops):
            for inp in op.inputs:
                last_read[inp] = i
        last_read[len(ops) - 1] = len(ops)
        live: Dict[int, int] = {}
        peak = 0
        for i, op in enumerate(ops):
            live[i] = binom(self.k, op.m)
            peak = max(peak, sum(live.values()))
            for j in list(live):
                if last_read.get(j, -1) <= i:
                    live.pop(j)
        return peak


def build_counting_plan(template: Template, root: Optional[int] = None) -> CountingPlan:
    k = template.k
    if template.is_tree:
        part = partition_template(template, root)
        tables: List[object] = []
        for sub in part.subs:
            if sub.is_leaf:
                tables.append(None)
            else:
                m = sub.size
                m_a = part.subs[sub.active].size
                tables.append(build_split_table(k, m, m_a))
        return CountingPlan(
            template=template,
            partition=part,
            k=k,
            tables=tuple(tables),
            automorphisms=tree_automorphisms(template),
        )
    prog = build_bag_program(template)
    tables = []
    for op in prog.ops:
        if op.kind == "extend":
            tables.append(build_split_table(k, op.m, 1))
        elif op.kind == "join":
            o1, o2 = (prog.ops[i] for i in op.inputs)
            overlap = len(set(o1.covered) & set(o2.covered))
            tables.append(build_union_split_table(k, o1.m, o2.m, overlap))
        else:  # leaf / forget
            tables.append(None)
    return CountingPlan(
        template=template,
        partition=None,
        k=k,
        tables=tuple(tables),
        automorphisms=graph_automorphisms(template),
        bag_program=prog,
    )


# ---------------------------------------------------------------------------
# SpMM implementations (high-level JAX; Pallas kernel lives in repro.kernels).
# ---------------------------------------------------------------------------


def spmm_edges(src: jnp.ndarray, dst: jnp.ndarray, n: int, m: jnp.ndarray) -> jnp.ndarray:
    """``B[i] = sum_{j in N(i)} M[j]`` via edge-list gather + segment-sum.

    Edges are sorted by ``dst`` (Graph canonical form) so the segment sum is
    contiguous.
    """
    return jax.ops.segment_sum(m[src], dst, num_segments=n, indices_are_sorted=True)


def spmm_ell(nbr: jnp.ndarray, mask: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """``B[i] = sum_d mask[i,d] * M[nbr[i,d]]`` — padded row-gather reduction."""
    gathered = m[nbr]  # (n, max_deg, C)
    return jnp.einsum("ndc,nd->nc", gathered, mask.astype(m.dtype))


def _ema_apply(
    m_a: jnp.ndarray,
    b: jnp.ndarray,
    idx_a: jnp.ndarray,
    idx_p: jnp.ndarray,
    init: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Vertex-local eMA: ``M_s[:, o] = sum_t M_a[:, idx_a[o,t]] * B[:, idx_p[o,t]]``.

    Loops over the (small) split axis; each step is a column gather + FMA with
    vector length |V| (the paper's column-major vectorization).  ``init`` lets
    shard_map callers pass a correctly axis-varying zero accumulator.
    """
    n = m_a.shape[0]
    n_out, n_splits = idx_a.shape

    def body(t, acc):
        ga = jnp.take(m_a, idx_a[:, t], axis=1)
        gp = jnp.take(b, idx_p[:, t], axis=1)
        return acc + ga * gp

    if init is None:
        init = jnp.zeros((n, n_out), dtype=m_a.dtype)
    return jax.lax.fori_loop(0, n_splits, body, init)


def _ema_apply_fused(
    m_a: jnp.ndarray,
    b: jnp.ndarray,
    idx_a: jnp.ndarray,
    idx_p: jnp.ndarray,
    init: jnp.ndarray,
) -> jnp.ndarray:
    """:func:`_ema_apply` on the engine's fused ``(n, B, C)`` layout.

    Column gathers run on axis 2; ``init`` fixes the accumulator shape and
    dtype (and, for shard_map callers, its varying axes).  Shared by the
    local engine backends and the mesh DP so the two cannot drift.
    """
    n_splits = idx_a.shape[1]
    accum = init.dtype

    def body(t, acc):
        ga = jnp.take(m_a, idx_a[:, t], axis=2).astype(accum)
        gp = jnp.take(b, idx_p[:, t], axis=2).astype(accum)
        return acc + ga * gp

    return jax.lax.fori_loop(0, n_splits, body, init)


def _fused_batch_apply(
    m_s: jnp.ndarray,
    m_a: jnp.ndarray,
    bcol: jnp.ndarray,
    idx_a: jnp.ndarray,
    idx_p: jnp.ndarray,
    valid: Optional[jnp.ndarray],
    accum_dtype: jnp.dtype,
) -> jnp.ndarray:
    """Fold one bucketed batch's eMA entries into the accumulator ``m_s``."""

    def body(j, acc):
        ia = jax.lax.dynamic_index_in_dim(idx_a, j, axis=1, keepdims=False)
        ip = jax.lax.dynamic_index_in_dim(idx_p, j, axis=1, keepdims=False)
        ga = jnp.take(m_a, ia, axis=2).astype(accum_dtype)
        gb = jnp.take(bcol, ip, axis=2).astype(accum_dtype)
        prod = ga * gb
        if valid is not None:  # mask padded entry slots (ragged buckets)
            va = jax.lax.dynamic_index_in_dim(valid, j, axis=1, keepdims=False)
            prod = prod * va[None, None, :].astype(accum_dtype)
        return acc + prod

    return jax.lax.fori_loop(0, idx_a.shape[1], body, m_s)


def fused_aggregate_ema(
    m_p: jnp.ndarray,
    m_a: jnp.ndarray,
    batches: Sequence[Tuple[int, int, jnp.ndarray, jnp.ndarray, jnp.ndarray]],
    n_out: int,
    spmm_fn: Callable[[jnp.ndarray], jnp.ndarray],
    accum_dtype: jnp.dtype = jnp.float32,
) -> jnp.ndarray:
    """Fused SpMM+eMA over the engine's ``(n, B, C)`` fused state.

    The execution model of the fused pipeline: the aggregate product
    ``A_G @ M_p`` is never materialized.  Per passive-column batch, only that
    batch's aggregate columns are computed (``spmm_fn`` applied to an
    ``(n, B, width)`` slice) and immediately consumed by the dense
    gather-FMA updates whose split's passive column falls in the batch
    (:func:`repro.core.colorsets.bucketed_split_entries` pre-buckets the
    split table).  Peak scratch per stage drops from the full
    ``(n, B, C_p)`` product (plus the backend's edge-wide gather at
    ``C_p`` columns) to a single ``width``-column slice of each.

    Args:
      m_p: ``(n, B, C_p)`` passive state (store dtype).
      m_a: ``(n, B, C_a)`` active state (store dtype).
      batches: bucketed split entries — ``(lo, width, idx_a, idx_p_local,
        valid)`` per batch, index arrays already device-resident (``valid``
        is ``None`` when the batch has no padded slots).
      n_out: output color-set count (``m_s`` columns).
      spmm_fn: the backend's neighbor reduction over a column *slice*;
        returns ``accum_dtype``.
      accum_dtype: FMA accumulation dtype (fp32 under the bf16 policy).

    Returns ``(n, B, n_out)`` in ``accum_dtype``.  Batch order and
    per-batch entry order are static, so results are deterministic and
    independent of the coloring-chunk size.

    Stages that read the *same* passive state should go through
    :func:`fused_aggregate_ema_grouped`, which shares each batch's
    aggregation across all of them — this function is the one-stage case.
    """
    return fused_aggregate_ema_grouped(
        m_p, [(m_a, batches, n_out)], spmm_fn, accum_dtype
    )[0]


def fused_aggregate_ema_grouped(
    m_p: jnp.ndarray,
    stages: Sequence[Tuple[jnp.ndarray, Sequence[Tuple], int]],
    spmm_fn: Callable[[jnp.ndarray], jnp.ndarray],
    accum_dtype: jnp.dtype = jnp.float32,
) -> List[jnp.ndarray]:
    """Shared-passive fusion: several stages consume one column-batch sweep.

    All ``stages`` read the same passive state ``m_p`` (same canonical
    passive sub-template, hence the same column count and bucketing), so the
    per-batch aggregate ``spmm_fn(slice)`` is computed ONCE per passive
    column batch and consumed by every stage's eMA entries for that batch —
    multi-template runs stop re-aggregating a shared passive per stage.
    This restores the memoized-SpMM-product sharing the two-pass pipeline
    had, without ever materializing the full ``A_G @ M_p`` product.

    Args:
      m_p: ``(n, B, C_p)`` shared passive state (store dtype).
      stages: per stage ``(m_a, batches, n_out)`` — the active state, the
        bucketed split entries over ``m_p``'s columns, and the output width.
      spmm_fn / accum_dtype: as in :func:`fused_aggregate_ema`.

    Returns one ``(n, B, n_out)`` array (``accum_dtype``) per stage, in
    stage order.  Per stage, batch order and entry order are identical to
    the ungrouped execution, so results are bit-exact with it.
    """
    n, bsz = m_p.shape[0], m_p.shape[1]
    outs = [jnp.zeros((n, bsz, n_out), accum_dtype) for _, _, n_out in stages]
    # Union of the stages' bucketed batches, keyed by batch start column.
    # Stages share C_p and the bucketing width, so equal `lo` => equal slice.
    sweep: Dict[int, Tuple[int, List[Tuple[int, Tuple]]]] = {}
    for s_idx, (_, batches, _) in enumerate(stages):
        for lo, width, idx_a, idx_p, valid in batches:
            prev = sweep.get(lo)
            if prev is not None and prev[0] != width:
                raise ValueError(
                    f"grouped stages disagree on batch width at column {lo}: "
                    f"{prev[0]} vs {width} (passive states not identical?)"
                )
            users = prev[1] if prev is not None else []
            users.append((s_idx, (idx_a, idx_p, valid)))
            sweep[lo] = (width, users)
    for lo in sorted(sweep):
        width, users = sweep[lo]
        cols = jax.lax.slice_in_dim(m_p, lo, lo + width, axis=2)
        bcol = spmm_fn(cols)  # (n, B, width) — the only aggregate transient
        for s_idx, (idx_a, idx_p, valid) in users:
            outs[s_idx] = _fused_batch_apply(
                outs[s_idx], stages[s_idx][0], bcol, idx_a, idx_p, valid, accum_dtype
            )
    return outs


def schedule_liveness(plans, canons, track_products: bool = False):
    """Last-read position for every shared DP state (and SpMM product).

    The multi-template schedule executes each canonical sub-template once
    (first occurrence across plans) and reads each plan's root at the end of
    that plan.  Returns ``free_at``: position -> list of keys (canonical
    strings, or ``("prod", canon)`` for memoized aggregate products when
    ``track_products``) that are dead after that position, so executors can
    drop them and peak memory matches Algorithm 5's in-place storage instead
    of growing with the number of stages.
    """
    executed = set()
    last_read = {}
    pos = 0
    for p_idx, plan in enumerate(plans):
        pc = canons[p_idx]
        if plan.partition is not None:
            for i, sub in enumerate(plan.partition.subs):
                if pc[i] in executed:
                    continue
                executed.add(pc[i])
                if not sub.is_leaf:
                    last_read[pc[sub.active]] = pos
                    last_read[pc[sub.passive]] = pos
                    if track_products:
                        last_read[("prod", pc[sub.passive])] = pos
                pos += 1
            last_read[pc[plan.partition.root_index]] = pos
            pos += 1
        else:
            # Bag plans: same first-occurrence / position discipline; bag ops
            # have no memoized aggregate products (extend SpMMs consume their
            # input directly), so track_products adds nothing here.
            for i, op in enumerate(plan.bag_program.ops):
                if pc[i] in executed:
                    continue
                executed.add(pc[i])
                for inp in op.inputs:
                    last_read[pc[inp]] = pos
                pos += 1
            last_read[pc[len(plan.bag_program.ops) - 1]] = pos
            pos += 1
    free_at = {}
    for key, p in last_read.items():
        free_at.setdefault(p, []).append(key)
    return free_at


def liveness_peak_columns(
    plans,
    canons,
    pad_unit: int = 1,
    track_products: bool = False,
) -> int:
    """Peak live M columns per coloring under the liveness-aware schedule.

    Simulates the multi-template DP with eager freeing: per executed stage
    the live set holds every not-yet-dead canonical state (columns padded up
    to ``pad_unit``), plus — when ``track_products`` — the memoized
    aggregate product of the stage's passive state.  ``track_products=False``
    models the fused pipeline, where no aggregate product ever exists.
    """
    def pad_cols(c: int) -> int:
        return ((c + pad_unit - 1) // pad_unit) * pad_unit

    k = plans[0].k
    free_at = schedule_liveness(plans, canons, track_products=track_products)
    executed = set()
    live = {}
    peak = 0
    pos = 0
    for p_idx, plan in enumerate(plans):
        pc = canons[p_idx]
        if plan.partition is not None:
            stage_widths = [binom(k, sub.size) for sub in plan.partition.subs]
            stage_prod = [
                (pc[sub.passive], binom(k, plan.partition.subs[sub.passive].size))
                if (not sub.is_leaf and track_products)
                else None
                for sub in plan.partition.subs
            ]
        else:
            stage_widths = [binom(k, op.m) for op in plan.bag_program.ops]
            stage_prod = [None] * len(stage_widths)
        for i, width in enumerate(stage_widths):
            if pc[i] in executed:
                continue
            executed.add(pc[i])
            live[pc[i]] = pad_cols(width)
            if stage_prod[i] is not None:
                prod_canon, prod_width = stage_prod[i]
                live.setdefault(("prod", prod_canon), pad_cols(prod_width))
            peak = max(peak, sum(live.values()))
            for key in free_at.get(pos, ()):
                live.pop(key, None)
            pos += 1
        peak = max(peak, sum(live.values()))
        for key in free_at.get(pos, ()):
            live.pop(key, None)
        pos += 1
    return peak


def liveness_peak_elements(plans, canons, n: int) -> int:
    """Peak live DP-state *elements* per coloring (vertex axes included).

    Generalizes :func:`liveness_peak_columns` to bag plans, where a state
    with ``r`` vertex axes holds ``n**r * C(k, m)`` elements per coloring.
    Tree states are the ``r = 1`` case, so for pure-tree plan lists this is
    exactly ``n * liveness_peak_columns(plans, canons)``.
    """
    k = plans[0].k
    free_at = schedule_liveness(plans, canons)
    executed = set()
    live = {}
    peak = 0
    pos = 0
    for p_idx, plan in enumerate(plans):
        pc = canons[p_idx]
        if plan.partition is not None:
            stage_elems = [n * binom(k, sub.size) for sub in plan.partition.subs]
        else:
            stage_elems = [
                (n ** len(op.axes)) * binom(k, op.m) for op in plan.bag_program.ops
            ]
        for i, elems in enumerate(stage_elems):
            if pc[i] in executed:
                continue
            executed.add(pc[i])
            live[pc[i]] = elems
            peak = max(peak, sum(live.values()))
            for key in free_at.get(pos, ()):
                live.pop(key, None)
            pos += 1
        peak = max(peak, sum(live.values()))
        for key in free_at.get(pos, ()):
            live.pop(key, None)
        pos += 1
    return peak


def count_colorful_vectorized(
    plan: CountingPlan,
    colors: jnp.ndarray,
    spmm_fn: Callable[[jnp.ndarray], jnp.ndarray],
    ema_fn: Optional[Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]] = None,
    dtype: jnp.dtype = jnp.float32,
) -> jnp.ndarray:
    """Algorithm 5: one coloring's colorful-embedding rooted-count total.

    Args:
      plan: static DP schedule.
      colors: ``(n,)`` int array of vertex colors in ``[0, k)``.
      spmm_fn: ``M -> A_G @ M`` — the pluggable neighbor-sum kernel.
      ema_fn: optional override of the eMA kernel (defaults to the fused
        column-gather FMA; the Pallas kernel plugs in here).

    Returns the scalar ``sum_i M_0(i, I_full)`` (un-normalized; see
    :func:`normalize_count`).
    """
    ema = ema_fn or _ema_apply
    if plan.partition is None:
        raise ValueError(
            f"count_colorful_vectorized is tree-only; template "
            f"{plan.template.name} has a bag program — use a CountingEngine"
        )
    n = colors.shape[0]
    k = plan.k
    leaf = jax.nn.one_hot(colors, k, dtype=dtype)  # rank({c}) == c

    slots: Dict[int, jnp.ndarray] = {}
    for i, sub in enumerate(plan.partition.subs):
        if sub.is_leaf:
            slots[i] = leaf
            continue
        table = plan.tables[i]
        m_a = slots[sub.active]
        m_p = slots[sub.passive]
        b = spmm_fn(m_p)  # SpMM over ALL passive columns at once
        idx_a = jnp.asarray(table.idx_a)
        idx_p = jnp.asarray(table.idx_p)
        slots[i] = ema(m_a, b, idx_a, idx_p)
        # Free children eagerly (Algorithm 5's in-place storage).
        del slots[sub.active], slots[sub.passive]

    root = plan.partition.root_index
    return jnp.sum(slots[root])


def count_colorful_traversal(plan: CountingPlan, graph: Graph, colors: np.ndarray) -> float:
    """Algorithm 2 (FASCIA traversal model), NumPy reference.

    The neighbor reduction ``sum_{j in N(i)} M_p(j, I_p)`` is recomputed for
    every (output color set, split) pair — the redundancy Figure 3 points at.
    """
    if plan.partition is None:
        raise ValueError(
            f"count_colorful_traversal is tree-only; template "
            f"{plan.template.name} has a bag program — use a CountingEngine"
        )
    n, k = graph.n, plan.k
    src, dst = graph.src, graph.dst
    leaf = np.zeros((n, k), dtype=np.float64)
    leaf[np.arange(n), colors] = 1.0

    slots: Dict[int, np.ndarray] = {}
    for i, sub in enumerate(plan.partition.subs):
        if sub.is_leaf:
            slots[i] = leaf
            continue
        table = plan.tables[i]
        m_a, m_p = slots[sub.active], slots[sub.passive]
        m_s = np.zeros((n, table.n_out), dtype=np.float64)
        for out in range(table.n_out):
            for t in range(table.n_splits):
                ia = int(table.idx_a[out, t])
                ip = int(table.idx_p[out, t])
                # The redundant per-split neighbor traversal:
                b_col = np.zeros(n, dtype=np.float64)
                np.add.at(b_col, dst, m_p[src, ip])
                m_s[:, out] += m_a[:, ia] * b_col
        slots[i] = m_s
        del slots[sub.active], slots[sub.passive]
    return float(slots[plan.partition.root_index].sum())


# ---------------------------------------------------------------------------
# Exact brute-force oracles (tiny graphs only).
# ---------------------------------------------------------------------------


def _injective_hom_count(
    graph: Graph,
    template: Template,
    accept: Callable[[np.ndarray], bool],
) -> int:
    """Count injective homomorphisms T -> G whose image satisfies ``accept``."""
    adj_g: List[np.ndarray] = []
    row_ptr, col_idx = graph.csr()
    for i in range(graph.n):
        adj_g.append(col_idx[row_ptr[i] : row_ptr[i + 1]])
    adj_t = template.adjacency()
    k = template.k
    # BFS order from vertex 0; each vertex after the first has a mapped parent.
    order = [0]
    parent = {0: -1}
    seen = {0}
    qi = 0
    while qi < len(order):
        u = order[qi]
        qi += 1
        for v in adj_t[u]:
            if v not in seen:
                seen.add(v)
                parent[v] = u
                order.append(v)
    pos = {v: i for i, v in enumerate(order)}

    count = 0
    mapping = np.full(k, -1, dtype=np.int64)
    used = np.zeros(graph.n, dtype=bool)

    def rec(depth: int) -> None:
        nonlocal count
        if depth == k:
            img = mapping[np.array(order)]
            if accept(img):
                count += 1
            return
        tv = order[depth]
        # Candidates: neighbors of the mapped parent's image.
        if depth == 0:
            candidates = range(graph.n)
        else:
            candidates = adj_g[mapping[parent[tv]]]
        # All already-mapped template-neighbors must be graph-neighbors.
        mapped_nbrs = [mapping[u] for u in adj_t[tv] if pos[u] < depth]
        for gv in candidates:
            gv = int(gv)
            if used[gv]:
                continue
            ok = all(np.any(adj_g[gv] == mn) for mn in mapped_nbrs)
            if not ok:
                continue
            mapping[tv] = gv
            used[gv] = True
            rec(depth + 1)
            used[gv] = False
            mapping[tv] = -1

    rec(0)
    return count


def brute_force_embeddings(graph: Graph, template: Template) -> float:
    """Exact count of non-induced embeddings of T in G (any template)."""
    homs = _injective_hom_count(graph, template, lambda img: True)
    return homs / graph_automorphisms(template)


def brute_force_colorful(graph: Graph, template: Template, colors: np.ndarray) -> float:
    """Exact count of *colorful* embeddings under a fixed coloring."""
    colors = np.asarray(colors)
    k = template.k

    def accept(img: np.ndarray) -> bool:
        return len(set(colors[img].tolist())) == k

    homs = _injective_hom_count(graph, template, accept)
    return homs / graph_automorphisms(template)


def normalize_count(raw_total: jnp.ndarray, plan: CountingPlan) -> jnp.ndarray:
    """``emb_estimate = raw / (P * |Aut(T)|)`` (Algorithm 1, line 8)."""
    p = colorful_probability(plan.k)
    return raw_total / (p * plan.automorphisms)
