"""Plan inspector CLI: ``python -m repro.plan <template> [...] [--graph SPEC]``.

Pretty-prints a :class:`~repro.plan.ir.TemplatePlan` — the stage schedule
(with canonical sharing and liveness frees), the shared-passive exec
groups, and the liveness peak — and, when a graph is given, binds a real
``CountingEngine`` to print the calibrated cost-model verdict (backend,
predicted resident/transient bytes, fusion slack, picked chunk).

Examples::

    python -m repro.plan u6
    python -m repro.plan path6 star6 bintree6 u6
    python -m repro.plan u7 --graph rmat:2048:20000:1
    python -m repro.plan u6 --graph grid:30:30 --backend ell --dtype bf16
    python -m repro.plan --template triangle --template square --graph er:500:2000

Non-tree templates (triangle, square, diamond, clique4, ...) print their
bag schedule — tree-decomposition ops (extend/forget/join), live axes,
decomposition width — alongside the same liveness and cost verdicts.

Templates of different vertex counts cannot share colorings, so the CLI
groups them by ``k`` and prints one plan (and one cost verdict) per group.

Graph specs: ``rmat:N:E[:SEED]``, ``er:N:E[:SEED]``, ``grid:R:C``.
"""

from __future__ import annotations

import argparse
import sys

from repro.core.graph import erdos_renyi_graph, grid_graph, rmat_graph
from repro.core.templates import get_template

from .ir import build_template_plan


def _parse_graph(spec: str):
    parts = spec.split(":")
    kind = parts[0]
    try:
        if kind == "rmat":
            n, e = int(parts[1]), int(parts[2])
            seed = int(parts[3]) if len(parts) > 3 else 0
            return rmat_graph(n, e, seed=seed), f"rmat(n={n}, edges={e}, seed={seed})"
        if kind == "er":
            n, e = int(parts[1]), int(parts[2])
            seed = int(parts[3]) if len(parts) > 3 else 0
            return (
                erdos_renyi_graph(n, e, seed=seed),
                f"erdos-renyi(n={n}, edges={e}, seed={seed})",
            )
        if kind == "grid":
            r, c = int(parts[1]), int(parts[2])
            return grid_graph(r, c), f"grid({r}x{c})"
    except (IndexError, ValueError) as exc:
        raise SystemExit(f"bad --graph spec {spec!r}: {exc}")
    raise SystemExit(f"unknown graph kind {kind!r} (rmat | er | grid)")


def _fmt_bytes(b: float) -> str:
    if b >= 2**20:
        return f"{b / 2**20:.2f} MiB"
    if b >= 2**10:
        return f"{b / 2**10:.1f} KiB"
    return f"{int(b)} B"


def _print_plan(plan) -> None:
    d = plan.describe()
    names = ", ".join(d["templates"])
    print(f"TemplatePlan: [{names}]  k={d['k']}")
    print(
        f"  {d['total_subs']} sub-templates -> {d['unique_canons']} unique canons "
        f"-> {d['stages']} scheduled stages ({d['positions']} positions incl. "
        f"root reads)"
    )
    print(
        f"  liveness peak: {d['peak_columns']} live M columns per coloring "
        f"(naive per-plan in-place bound: {d['naive_peak_columns']})"
    )
    print(
        f"  widest passive state: {d['max_passive_columns']} cols | widest "
        f"stage (a+p+out): {d['max_stage_columns']} cols"
    )
    print(f"  split tables (k, m, m_a): {d['table_keys'] or '-'}")
    if d.get("bag_stages"):
        widths = ", ".join(
            f"{name}={w}" for name, w in d["decomposition_widths"].items()
        )
        print(
            f"  bag stages: {d['bag_stages']} (max live axes "
            f"{d['max_bag_axes']}) | decomposition widths: {widths}"
        )
        print(
            f"  join tables (k, m1, m2, overlap): {d['join_table_keys'] or '-'}"
        )

    print("\n  pos  stage        kind  cols  active+passive -> out          frees")
    by_pos = {s.position: s for s in plan.stages}
    tmpl_names = [t.name for t in plan.templates]
    pos = 0
    for p_idx, cplan in enumerate(plan.counting_plans):
        if cplan.partition is None:
            ops = cplan.bag_program.ops
            for i, op in enumerate(ops):
                s = by_pos.get(pos)
                if s is None or (s.plan_idx, s.sub_idx) != (p_idx, i):
                    continue  # duplicate canon: executed earlier, no position
                frees = ",".join(plan.free_at.get(pos, ())) or "-"
                label = f"{tmpl_names[p_idx]}[{i}]"
                axes = ",".join(map(str, op.axes)) or "-"
                if op.kind == "leaf":
                    body = f"leaf  {s.columns:4d}  {'one-hot coloring':28s}"
                else:
                    bits = [f"axes[{axes}]"]
                    if op.kind == "extend":
                        bits.append(f"+v{op.vertex}")
                        if op.spmm_vertex is not None:
                            bits.append(f"spmm(v{op.spmm_vertex})")
                        if op.mask_vertices:
                            bits.append(
                                "mask("
                                + ",".join(f"v{v}" for v in op.mask_vertices)
                                + ")"
                            )
                    elif op.kind == "join":
                        bits.append("color-conv")
                    if op.forget_vertices:
                        bits.append(
                            "fgt("
                            + ",".join(f"v{v}" for v in op.forget_vertices)
                            + ")"
                        )
                    kind = {"extend": "ext ", "join": "join", "forget": "fgt "}[
                        op.kind
                    ]
                    body = f"{kind}  {s.columns:4d}  {' '.join(bits):28s}"
                print(f"  {pos:3d}  {label:11s}  {body}  {frees}")
                pos += 1
            frees = ",".join(plan.free_at.get(pos, ())) or "-"
            print(
                f"  {pos:3d}  {tmpl_names[p_idx]:11s}  root        "
                f"{'sum over colors+vertices':28s}  {frees}"
            )
            pos += 1
            continue
        for i, _sub in enumerate(cplan.partition.subs):
            s = by_pos.get(pos)
            if s is None or (s.plan_idx, s.sub_idx) != (p_idx, i):
                # duplicate canon: executed earlier, takes no position
                continue
            frees = ",".join(plan.free_at.get(pos, ())) or "-"
            label = f"{tmpl_names[s.plan_idx]}[{s.sub_idx}]"
            if s.is_leaf:
                body = f"leaf  {s.columns:4d}  {'one-hot coloring':28s}"
            else:
                arrow = (
                    f"{s.active_columns}+{s.passive_columns} -> {s.columns}"
                )
                body = f"ema   {s.columns:4d}  {arrow:28s}"
            print(f"  {pos:3d}  {label:11s}  {body}  {frees}")
            pos += 1
        frees = ",".join(plan.free_at.get(pos, ())) or "-"
        print(
            f"  {pos:3d}  {tmpl_names[p_idx]:11s}  root        "
            f"{'sum over colors+vertices':28s}  {frees}"
        )
        pos += 1

    shared = {l: m for l, m in plan.exec_groups.items() if len(m) > 1}
    if shared:
        print("\n  shared-passive exec groups (one column-batch sweep each):")
        for (p, i), members in shared.items():
            mem = ", ".join(f"{tmpl_names[q]}[{j}]" for q, j in members)
            print(f"    leader {tmpl_names[p]}[{i}] <- [{mem}]")
    else:
        print("\n  shared-passive exec groups: none (all singletons)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.plan",
        description="Inspect the TemplatePlan IR (and, with --graph, the "
        "calibrated cost-model verdict) for a template set.",
    )
    ap.add_argument(
        "templates", nargs="*", help="template names (same k), e.g. u6 or triangle"
    )
    ap.add_argument(
        "--template",
        action="append",
        default=[],
        dest="extra_templates",
        metavar="NAME",
        help="additional template (repeatable) — same namespace as the "
        "positionals; graphlets like triangle/square/diamond compile to "
        "bag schedules",
    )
    ap.add_argument("--graph", help="rmat:N:E[:SEED] | er:N:E[:SEED] | grid:R:C")
    ap.add_argument("--backend", default="auto", help="engine backend (default auto)")
    ap.add_argument("--dtype", default="fp32", help="dtype policy: fp32 | bf16")
    ap.add_argument(
        "--budget", type=int, default=None, help="memory budget bytes for the picker"
    )
    ap.add_argument("--column-batch", type=int, default=None)
    ap.add_argument("--chunk-size", type=int, default=None)
    ap.add_argument(
        "--mesh-shards",
        type=int,
        default=None,
        metavar="D",
        help="print the mesh comm model's per-stage verdict (blocking vs "
        "pipelined ring, wire bytes, overlap efficiency) for a D-shard "
        "1-D mesh — needs --graph",
    )
    args = ap.parse_args(argv)
    if args.mesh_shards is not None and not args.graph:
        ap.error("--mesh-shards needs --graph (the comm model prices real edges)")

    names = list(args.templates) + list(args.extra_templates)
    if not names:
        ap.error("need at least one template (positional or --template)")
    templates = [get_template(name) for name in names]
    # templates of different k cannot share colorings — one plan per k
    groups: dict = {}
    for t in templates:
        groups.setdefault(t.k, []).append(t)

    graph = gdesc = None
    if args.graph:
        graph, gdesc = _parse_graph(args.graph)

    for g_idx, (k, group) in enumerate(sorted(groups.items())):
        if g_idx:
            print("\n" + "=" * 72 + "\n")
        plan = build_template_plan(group)
        _print_plan(plan)
        if graph is not None:
            from repro.core.engine import DEFAULT_MEMORY_BUDGET_BYTES, CountingEngine

            eng = CountingEngine(
                graph,
                group,
                backend=args.backend,
                dtype_policy=args.dtype,
                memory_budget_bytes=args.budget or DEFAULT_MEMORY_BUDGET_BYTES,
                column_batch=args.column_batch,
                chunk_size=args.chunk_size,
            )
            d = eng.describe()
            mem = d["memory"]
            print(f"\nCost model on {gdesc}:")
            print(
                f"  backend: {d['backend']['name']} "
                f"({d['backend']['source']}: {d['backend']['reason']})"
            )
            print(
                f"  dtype: store={d['dtype_policy']['store']} "
                f"accum={d['dtype_policy']['accum']} | "
                f"column_batch={d['column_batch']}"
            )
            print(
                f"  predicted bytes/coloring: "
                f"{_fmt_bytes(mem['bytes_per_coloring'])} "
                f"(resident {_fmt_bytes(mem['predicted_resident_bytes'])} + "
                f"transient {_fmt_bytes(mem['predicted_transient_bytes'])}, "
                f"fusion slack {mem['fusion_slack']:.4f})"
            )
            print(
                f"  chunk: {d['chunk_size']} colorings under a "
                f"{_fmt_bytes(mem['budget_bytes'])} budget -> predicted peak "
                f"{_fmt_bytes(eng.predicted_peak_bytes())}"
            )
            if args.mesh_shards is not None:
                _print_comm_schedule(eng.cost, args.mesh_shards, args.column_batch)
    return 0


def _print_comm_schedule(cost, n_shards: int, column_batch) -> None:
    """The comm model's per-stage verdict for a 1-D ``n_shards`` mesh —
    the same ``CommSchedule`` the MeshBackend resolves (absent an
    env/explicit override) and ``describe()['comm']`` reports."""
    from .cost import mesh_link_bytes_per_us

    cb = column_batch or cost.pick_mesh_column_batch()
    schedules = cost.mesh_comm_schedules(n_shards, column_batch=cb)
    print(
        f"\nMesh comm schedule ({n_shards} shards, column_batch={cb}, "
        f"link {mesh_link_bytes_per_us():.0f} B/us):"
    )
    print(
        "  stage      mode       wire        comm_us  compute_us  overlap  "
        "reason"
    )
    for leader, s in sorted(schedules.items()):
        d = s.describe()
        print(
            f"  {leader[0]}:{leader[1]:<7d} {d['mode']:10s} "
            f"{_fmt_bytes(d['wire_bytes']):>10s}  {d['comm_us']:7.1f}  "
            f"{d['compute_us']:10.1f}  {d['overlap_efficiency']:7.2f}  "
            f"{d['reason']}"
        )


if __name__ == "__main__":
    sys.exit(main())
