"""The unified cost model: resource predictions for every execution target.

One :class:`CostModel` per (plan, graph, dtype) owns everything the engine
used to scatter across backends and the chunk picker:

* the **resident** figure — ``n * TemplatePlan.peak_columns`` live M-matrix
  elements per coloring (per shard on the mesh target, padded to the
  all-gather batch);
* the **transient** formulas per target — one fused ``column_batch``-wide
  slice of the backend's gather scratch (edge messages, padded rows, SELL
  groups, the all-gather buffer);
* **column-batch picking** — the fused-slice width per target;
* **chunk picking** — the largest coloring chunk whose live footprint fits
  the memory budget, with the analytic byte model corrected by the

**fusion-slack factor**: the analytic model is compared against XLA's
measured temp allocation on every bench run
(``CountingEngine.compiled_memory_analysis``) and the predicted/actual
ratios are committed as ``memory_model`` rows in ``BENCH_counting.json``.
:func:`load_fusion_slack` folds their geometric mean back into the picker
(effective bytes = analytic bytes / slack), so the picker stops trusting
the analytic model blindly.  With no bench rows the factor is a safe 1.0;
whenever calibration is applied it is logged on the ``repro.plan`` logger.
"""

from __future__ import annotations

import json
import logging
import math
import os
from dataclasses import dataclass
from typing import Dict, Optional

import jax.numpy as jnp

__all__ = [
    "CostModel",
    "AdmissionEstimate",
    "admission_estimate",
    "CommSchedule",
    "LadderRung",
    "degradation_ladder",
    "RankedCandidate",
    "load_fusion_slack",
    "load_backend_calibration",
    "fusion_slack_factor",
    "mesh_link_bytes_per_us",
    "pick_chunk_size",
    "DEFAULT_MEMORY_BUDGET_BYTES",
    "MAX_CHUNK_SIZE",
    "LOCAL_COLUMN_BATCH",
    "MESH_COLUMN_BATCH",
    "MESH_LINK_BYTES_PER_US",
    "MESH_LINK_ENV_VAR",
    "RING_STEP_OVERHEAD_US",
    "SLACK_CLAMP",
    "BENCH_ENV_VAR",
    "CALIBRATION_CLAMP",
]

logger = logging.getLogger("repro.plan")

#: Default live-footprint budget for one chunk of colorings (bytes).  Sized
#: for the CPU/laptop case; on real TPUs pass the per-core VMEM/HBM figure.
DEFAULT_MEMORY_BUDGET_BYTES = 32 * 1024 * 1024

#: Hard cap on colorings fused into one chunk (diminishing returns beyond).
MAX_CHUNK_SIZE = 64

#: Default passive columns per fused SpMM+eMA slice on the local backends.
#: Empirically (2-core XLA:CPU interleaved A/B on the rmat2k bench graphs):
#: 16 beats both narrower slices (the per-call segment-sum fixed cost is
#: paid more often) and the full-width two-pass dataflow (whose edge-wide
#: transient thrashes cache), while keeping the chunk picker's fused
#: transient small enough to grow coloring chunks 2-4x over the seed.
LOCAL_COLUMN_BATCH = 16

#: Default passive columns per all-gather collective on the mesh target.
MESH_COLUMN_BATCH = 128

#: Calibration ratios outside this band are treated as measurement noise
#: (a wildly off bench row must not starve or blow the chunk picker).
SLACK_CLAMP = (0.5, 2.0)

#: Environment override for the bench file the slack factor is read from.
BENCH_ENV_VAR = "REPRO_FUSION_SLACK_BENCH"

#: Per-backend calibration ratios outside this band are treated as noise —
#: the lattice is a *ranker*, a 100x ratio would let one bad probe freeze a
#: backend out of every future candidate set.
CALIBRATION_CLAMP = (0.1, 10.0)

#: Nominal cost of one gathered/FMA'd element in the per-stage work model
#: (microseconds; absolute scale is arbitrary — the lattice only ranks).
WORK_ELEMENT_US = 1e-3

#: Fixed cost per fused column-batch sweep call (dispatch + segment-sum /
#: einsum setup) — what makes narrow column batches predictedly worse.
SWEEP_OVERHEAD_US = 12.0

#: Fixed per-chunk-launch cost, amortized over the chunk's colorings —
#: what makes tiny chunks predictedly worse.
LAUNCH_OVERHEAD_US = 150.0

#: Nominal mesh link bandwidth (bytes per microsecond) for the comm model —
#: ~4 GB/s, a conservative single-NIC / host-interconnect figure.  On real
#: ICI calibrate via :data:`MESH_LINK_ENV_VAR`; absolute scale only shifts
#: the blocking/pipelined crossover, the comm model still ranks.
MESH_LINK_BYTES_PER_US = 4000.0

#: Environment override (float, bytes/us) for the link-bandwidth constant —
#: the comm model's calibration knob.
MESH_LINK_ENV_VAR = "REPRO_MESH_LINK_BYTES_PER_US"

#: Fixed cost per ring step (ppermute dispatch + slice bookkeeping): the
#: term that keeps narrow stages on the blocking path, where one all-gather
#: beats ``n_shards`` tiny hops.
RING_STEP_OVERHEAD_US = 2.0


def mesh_link_bytes_per_us() -> float:
    """The comm model's link bandwidth, env-calibratable (bytes/us > 0).

    Bad values warn once and fall back to the default — cost modeling must
    never crash on a typo'd env var."""
    raw = os.environ.get(MESH_LINK_ENV_VAR, "").strip()
    if not raw:
        return MESH_LINK_BYTES_PER_US
    try:
        val = float(raw)
        if val > 0:
            return val
    except ValueError:
        pass
    if raw not in _BAD_LINK_VALUES_WARNED:
        _BAD_LINK_VALUES_WARNED.add(raw)
        logger.warning(
            "%s=%r is not a positive float — using the default %.0f bytes/us",
            MESH_LINK_ENV_VAR, raw, MESH_LINK_BYTES_PER_US,
        )
    return MESH_LINK_BYTES_PER_US


_BAD_LINK_VALUES_WARNED: set = set()

#: memoized slack factors, keyed by resolved bench path ('' = missing).
_SLACK_CACHE: Dict[str, float] = {}


def _default_bench_path() -> Optional[str]:
    env = os.environ.get(BENCH_ENV_VAR, "").strip()
    if env:
        return env
    # src/repro/plan/cost.py -> repo root (the committed bench lives there)
    root = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    )
    return os.path.join(root, "BENCH_counting.json")


def load_fusion_slack(path: Optional[str] = None) -> float:
    """Empirical fusion-slack factor from committed ``memory_model`` rows.

    Each row's ``derived`` field records ``predicted_over_actual`` — the
    analytic byte model divided by XLA's measured temp allocation for one
    bench engine config.  The factor returned is the geometric mean of the
    ratios, clamped to :data:`SLACK_CLAMP`; ``< 1`` means the analytic
    model under-predicts, so the picker inflates its byte estimates by
    ``1 / slack``.  **Safe default**: 1.0 whenever the bench file or the
    rows are missing or unparsable — the picker then behaves exactly like
    the uncalibrated analytic model.  Applied calibration is logged once
    per path on the ``repro.plan`` logger.
    """
    resolved = path if path is not None else _default_bench_path()
    key = resolved or ""
    if key in _SLACK_CACHE:
        return _SLACK_CACHE[key]
    slack = 1.0
    ratios = []
    try:
        with open(resolved) as fh:
            bench = json.load(fh)
        rows = bench.get("rows", []) if isinstance(bench, dict) else []
        for row in rows:
            if not isinstance(row, dict):
                continue
            if "memory_model" not in str(row.get("name", "")):
                continue
            fields = {}
            for part in str(row.get("derived", "")).split(";"):
                if "=" in part:
                    name, _, val = part.partition("=")
                    fields[name] = val
            try:
                ratio = float(fields["predicted_over_actual"])
                # rows written by a calibrated picker already fold a slack
                # into their prediction; multiply it back out so the loader
                # always sees the RAW analytic-model ratio (fixed point:
                # re-benching with calibration on does not double-correct)
                ratio *= float(fields.get("applied_fusion_slack", 1.0))
                if ratio > 0:  # '%.3f'-rounded zeros would poison the mean
                    ratios.append(ratio)
            except (KeyError, ValueError):
                pass
        if ratios:
            mean_log = sum(math.log(r) for r in ratios) / len(ratios)
            slack = min(max(math.exp(mean_log), SLACK_CLAMP[0]), SLACK_CLAMP[1])
            logger.info(
                "fusion-slack calibration applied: factor=%.4f from %d "
                "memory_model bench rows (%s)",
                slack,
                len(ratios),
                resolved,
            )
        else:
            logger.debug(
                "no memory_model rows in %s — fusion slack defaults to 1.0",
                resolved,
            )
    except (OSError, ValueError, TypeError, AttributeError, KeyError) as exc:
        logger.debug(
            "fusion-slack bench unavailable (%s) — defaulting to 1.0", exc
        )
    _SLACK_CACHE[key] = slack
    return slack


def fusion_slack_factor() -> float:
    """The memoized default-path slack (what engines constructed without an
    explicit ``fusion_slack`` use)."""
    return load_fusion_slack()


def load_backend_calibration(path: Optional[str] = None) -> Dict[str, float]:
    """Per-backend measured/predicted cost ratios from the tuning cache.

    The generalization of the fusion-slack mechanism to *time*: every
    tuning run records, for each uniform candidate it measured, the ratio
    of measured us-per-coloring to the lattice's raw (uncalibrated)
    prediction; :meth:`CostModel.candidate_lattice` multiplies each
    backend's predicted cost by its ratio, so rankings improve with every
    run even for workloads never tuned directly.  Ratios are clamped to
    :data:`CALIBRATION_CLAMP`; a missing/corrupt cache yields ``{}`` (the
    uncalibrated analytic ranking) — same safe-default contract as
    :func:`load_fusion_slack`.
    """
    # local import: repro.tune.cache is a leaf over repro.tune.config only
    from repro.tune.cache import load_calibration

    out = {}
    for name, ratio in load_calibration(path).items():
        out[name] = min(max(float(ratio), CALIBRATION_CLAMP[0]), CALIBRATION_CLAMP[1])
    return out


def _dense_work_advantage() -> int:
    # exec.select owns the constant (it imports nothing from plan)
    from repro.exec.select import DENSE_WORK_ADVANTAGE

    return DENSE_WORK_ADVANTAGE


@dataclass(frozen=True)
class RankedCandidate:
    """One point of the tuner's candidate lattice.

    ``predicted_us`` is the calibrated per-coloring cost estimate used for
    ranking/pruning; ``raw_us`` is the same figure *without* per-backend
    calibration (what measured ratios are computed against, so calibration
    reaches a fixed point instead of compounding run over run).
    """

    config: object  # TuningConfig (typed loosely: repro.tune is downstream)
    predicted_us: float
    raw_us: float


def pick_chunk_size(
    bytes_per_coloring: int,
    memory_budget_bytes: int,
    max_chunk: int = MAX_CHUNK_SIZE,
) -> int:
    """Largest chunk whose live footprint stays under the budget (>= 1)."""
    if bytes_per_coloring <= 0:
        return max_chunk
    return max(1, min(max_chunk, int(memory_budget_bytes // bytes_per_coloring)))


@dataclass(frozen=True)
class AdmissionEstimate:
    """Predicted footprint of one query, for serving-layer load shedding.

    Computed from the plan alone (no engine, no device operands, no
    compile), so the front-end can price a query at submit time in
    microseconds.  ``resident_bytes`` is the calibrated per-coloring
    live-DP-state figure; ``chunk_bytes`` is what one launch of the
    engine that would serve this query keeps live
    (``chunk_size * resident_bytes`` — the admission currency the
    front-end budgets against).  The backend gather transient is excluded
    on purpose: it is backend-geometry-specific and only known once an
    engine binds, so admission prices the dominant, backend-independent
    term and stays conservative-but-cheap.
    """

    resident_elements: int
    resident_bytes: int  # calibrated, per coloring
    chunk_size: int
    chunk_bytes: int  # resident_bytes * chunk_size — one launch's residency
    peak_columns: int


def admission_estimate(
    graph,
    templates,
    *,
    store_dtype=jnp.float32,
    chunk_size: Optional[int] = None,
    memory_budget_bytes: int = DEFAULT_MEMORY_BUDGET_BYTES,
    fusion_slack: Optional[float] = None,
) -> AdmissionEstimate:
    """Price a ``(graph, templates)`` query without building an engine.

    Plans the template set (:func:`repro.plan.ir.build_template_plan` is
    pure and host-side), then reads the :class:`CostModel` resident
    formula — the same one the engine's chunk picker uses, including the
    empirical fusion-slack calibration — so the admission figure and the
    engine's own ``predicted_peak_bytes()`` agree on the resident term.
    With no explicit ``chunk_size`` the chunk is picked against
    ``memory_budget_bytes`` exactly as an engine construction would.
    """
    from .ir import build_template_plan  # local: keeps import cycles out

    plan = build_template_plan(list(templates))
    cm = CostModel(plan, graph, store_dtype, fusion_slack=fusion_slack)
    resident = cm.resident_elements()
    per_coloring = cm.bytes_per_coloring(0, resident)
    chunk = (
        int(chunk_size)
        if chunk_size
        else cm.pick_chunk_size(per_coloring, memory_budget_bytes)
    )
    return AdmissionEstimate(
        resident_elements=resident,
        resident_bytes=per_coloring,
        chunk_size=chunk,
        chunk_bytes=per_coloring * chunk,
        peak_columns=plan.peak_columns,
    )


@dataclass(frozen=True)
class CommSchedule:
    """One DP stage's plan-time communication decision on the mesh target.

    ``mode`` is ``"blocking"`` (one all-gather per column batch) or
    ``"pipelined"`` (the double-buffered ring; ``ring_steps == n_shards``
    ``ppermute`` hops per batch, the next row slice in flight while the
    current one's edge messages are computed).  ``wire_bytes`` is the
    per-shard, per-coloring bytes on the wire for the whole stage;
    ``comm_us`` / ``compute_us`` are its modeled transfer and per-shard
    SpMM+eMA times; ``overlap_efficiency`` is the fraction of the wire
    time the ring hides under compute (``min(1, compute_step /
    comm_step)``).  ``reason`` records why the mode was picked (or
    forced).
    """

    stage: "Tuple[int, int]"  # exec-group leader (plan_idx, sub_idx)
    mode: str
    ring_steps: int  # 1 for blocking, n_shards for pipelined
    slice_rows: int  # rows_per_shard — the circulated slice height
    slice_cols: int  # column_batch — the circulated slice width
    wire_bytes: int
    comm_us: float
    compute_us: float
    overlap_efficiency: float
    reason: str

    def describe(self) -> Dict:
        return {
            "stage": list(self.stage),
            "mode": self.mode,
            "ring_steps": self.ring_steps,
            "slice_rows": self.slice_rows,
            "slice_cols": self.slice_cols,
            "wire_bytes": self.wire_bytes,
            "comm_us": round(self.comm_us, 3),
            "compute_us": round(self.compute_us, 3),
            "overlap_efficiency": round(self.overlap_efficiency, 4),
            "reason": self.reason,
        }


@dataclass(frozen=True)
class LadderRung:
    """One step of the memory degradation ladder (see
    :func:`degradation_ladder`)."""

    chunk_size: int
    column_batch: Optional[int]  # None = keep the engine's auto-pick
    backend: Optional[str]  # None = keep the configured backend
    action: str  # "halve_chunk" | "shrink_columns" | "fallback_backend"


def degradation_ladder(
    chunk_size: int,
    column_batch: Optional[int],
    backend: str,
) -> "list[LadderRung]":
    """The ordered retreat a memory failure walks before a query is
    rejected.

    Cheapest-first — each rung trades throughput for footprint along a
    knob the cost model already prices (so ``admission_estimate`` can
    re-price every rung without building anything):

    1. **halve ``chunk_size``** down to 1: the chunk is the multiplier on
       the whole live footprint, so halving it halves the launch residency
       with zero effect on results (estimates are bit-exact across chunk
       sizes — the engine invariant the retry path already leans on);
    2. **shrink ``column_batch``** (halving from its configured width down
       to 1, chunk pinned at 1): narrows the fused-slice transient;
    3. **fall back to the ``edges`` backend**: the smallest-transient
       executor (no padded rows, no SELL slots, no dense adjacency).

    Returns the rungs *below* the given configuration; an exhausted ladder
    (empty list / no rungs left) means the query genuinely cannot fit and
    fails with ``memory_exhausted``.
    """
    rungs = []
    chunk = int(chunk_size)
    while chunk > 1:
        chunk //= 2
        rungs.append(
            LadderRung(
                chunk_size=chunk, column_batch=None, backend=None,
                action="halve_chunk",
            )
        )
    cb = int(column_batch) if column_batch else LOCAL_COLUMN_BATCH
    while cb > 1:
        cb //= 2
        rungs.append(
            LadderRung(
                chunk_size=1, column_batch=cb, backend=None,
                action="shrink_columns",
            )
        )
    if backend not in ("edges", "custom", "mesh"):
        rungs.append(
            LadderRung(
                chunk_size=1, column_batch=1, backend="edges",
                action="fallback_backend",
            )
        )
    return rungs


class CostModel:
    """Resource predictions for one ``TemplatePlan`` on one graph.

    All element counts are *store-dtype elements per coloring*; byte
    figures multiply by the store itemsize and divide by the fusion-slack
    factor, so everything downstream (the chunk picker, ``describe()``,
    the bench calibration rows) sees one consistent, calibrated model.

    Operand-geometry arguments (``sell_padded_slots``, the mesh shard
    shape) are supplied by the bound backend — the formulas live here, the
    measurements live with the operands.
    """

    def __init__(
        self,
        plan,
        graph,
        store_dtype=jnp.float32,
        *,
        fusion_slack: Optional[float] = None,
    ):
        self.plan = plan
        self.graph = graph
        self.itemsize = jnp.dtype(store_dtype).itemsize
        self.fusion_slack = (
            load_fusion_slack() if fusion_slack is None else float(fusion_slack)
        )
        if not SLACK_CLAMP[0] <= self.fusion_slack <= SLACK_CLAMP[1]:
            raise ValueError(
                f"fusion_slack {self.fusion_slack} outside sane band {SLACK_CLAMP}"
            )

    # -- column-batch picking ------------------------------------------------

    def pick_local_column_batch(self) -> int:
        """Fused-slice width for the single-device backends."""
        return min(LOCAL_COLUMN_BATCH, self.plan.max_passive_columns)

    def pick_mesh_column_batch(self) -> int:
        """Columns per all-gather collective on the mesh target."""
        return min(MESH_COLUMN_BATCH, max(self.plan.max_passive_columns, self.plan.k))

    # -- local targets -------------------------------------------------------

    def resident_elements(self) -> int:
        """Live DP-state elements one coloring keeps resident.

        Tree-only plans: ``n`` rows times the plan's liveness-aware peak
        columns (unchanged).  Plans with bag stages use the element-level
        liveness peak — a bag state over ``r`` live axes is an
        ``n**r * C(k, m)`` tensor, so the row factor is no longer uniform.
        """
        if getattr(self.plan, "has_bag_stages", False):
            return self.plan.peak_elements(self.graph.n)
        return self.graph.n * self.plan.peak_columns

    def transient_elements(
        self,
        target: str,
        column_batch: int,
        *,
        sell_padded_slots: Optional[int] = None,
    ) -> int:
        """Widest per-stage scratch one coloring needs on ``target``.

        One fused slice: the backend's gather intermediate plus the
        aggregated ``(n, column_batch)`` slice — never the full passive
        width (that is the fused pipeline's whole point).

        Plans with bag stages take the max with the bag-op scratch
        (:meth:`bag_transient_elements`) — bag-join contractions run
        un-batched over the flattened state, so their slice can dominate.
        """
        g = self.graph
        if target in ("edges", "custom"):
            out = (g.num_directed + g.n) * column_batch
        elif target == "ell":
            out = (g.n * max(g.max_degree(), 1) + g.n) * column_batch
        elif target == "sell":
            if sell_padded_slots is None:
                raise ValueError("sell transient needs the built SELL geometry")
            out = (sell_padded_slots + g.n) * column_batch
        elif target == "dense":
            out = g.n * column_batch
        elif target == "blocked":
            # transposed-layout staging of one stage's operands/output; no
            # edge-wide or (n, C_p) aggregate intermediate exists
            out = g.n * self.plan.max_stage_columns
        else:
            raise ValueError(f"unknown cost target {target!r}")
        if getattr(self.plan, "has_bag_stages", False):
            out = max(
                out,
                self.bag_transient_elements(
                    target, sell_padded_slots=sell_padded_slots
                ),
            )
        return out

    def bag_transient_elements(
        self, target: str, *, sell_padded_slots: Optional[int] = None
    ) -> int:
        """Widest per-bag-op scratch one coloring needs on ``target``.

        Two shapes compete: the SpMM contraction of an ``extend`` runs the
        backend's gather intermediate over the *flattened* trailing width
        ``n**(r_in - 1) * C(k, m_in)`` (bag contractions are not
        column-batched), and the color-table loop of an extend/join holds
        two gathered operands plus the accumulator — three output-state
        tensors of ``n**r_out * C(k, m_out)`` elements.
        """
        # local import: core.engine imports this module at load time
        from repro.core.colorsets import binom

        g = self.graph
        if target in ("edges", "custom"):
            per_col = g.num_directed + g.n
        elif target == "ell":
            per_col = g.n * max(g.max_degree(), 1) + g.n
        elif target == "sell":
            if sell_padded_slots is None:
                raise ValueError("sell transient needs the built SELL geometry")
            per_col = sell_padded_slots + g.n
        elif target in ("dense", "blocked"):
            per_col = g.n
        else:
            raise ValueError(f"unknown cost target {target!r}")
        worst = 0
        for cplan in self.plan.counting_plans:
            if cplan.partition is not None:
                continue
            ops = cplan.bag_program.ops
            for op in ops:
                if op.kind == "leaf":
                    continue
                if op.kind == "extend" and op.spmm_vertex is not None:
                    src = ops[op.inputs[0]]
                    flat = g.n ** (len(src.axes) - 1) * binom(cplan.k, src.m)
                    worst = max(worst, per_col * flat)
                # gathered active/passive operands + the term accumulator
                r_out = len(op.axes) + len(op.forget_vertices)
                worst = max(worst, 3 * g.n**r_out * binom(cplan.k, op.m))
        return worst

    # -- mesh target (per shard!) --------------------------------------------

    def mesh_transient_elements(
        self, n_padded: int, edges_per_shard: int, column_batch: int
    ) -> int:
        """Per-shard collective scratch: one all-gathered column batch
        plus the per-shard edge message gather."""
        return (n_padded + edges_per_shard) * column_batch

    def mesh_resident_elements(
        self, rows_per_shard: int, column_batch: int, ema_mode: str = "streamed"
    ) -> int:
        """Per-shard live DP state: local rows times the liveness-aware
        peak of padded M columns (memoized SpMM products count too in the
        non-streamed eMA modes)."""
        peak = self.plan.padded_peak_columns(
            pad_unit=column_batch, track_products=(ema_mode != "streamed")
        )
        return rows_per_shard * peak

    def comm_schedule(
        self,
        leader,
        n_shards: int,
        *,
        column_batch: int,
        rows_per_shard: Optional[int] = None,
        edges_per_shard: Optional[int] = None,
        link_bytes_per_us: Optional[float] = None,
        forced: Optional[str] = None,
    ) -> "CommSchedule":
        """Blocking vs pipelined for one exec group's mesh SpMM sweeps.

        Per stage, per shard, per coloring the collective moves
        ``(n_shards - 1) * rows * C_p_padded`` store elements regardless of
        mode; the ring buys back the fraction of that transfer it can hide
        under the stage's per-shard compute (edge-bucket gather + eMA).
        The decision rule: pipeline iff the predicted hidden time exceeds
        the ring's own dispatch overhead
        (``n_batches * n_shards * RING_STEP_OVERHEAD_US``).  ``forced``
        (``"blocking"`` | ``"pipelined"``) records an env/caller override
        verbatim — the model still fills in the diagnostic fields.
        """
        from repro.core.colorsets import binom  # local: cycle-free

        p_idx, i = leader
        cplan = self.plan.counting_plans[p_idx]
        sub = cplan.partition.subs[i]
        passive_cols = binom(cplan.k, cplan.partition.subs[sub.passive].size)
        cb = max(1, int(column_batch))
        n_batches = max(1, math.ceil(passive_cols / cb))
        padded_cols = n_batches * cb
        rows = (
            int(rows_per_shard)
            if rows_per_shard
            else max(1, -(-self.graph.n // max(1, n_shards)))
        )
        edges = (
            int(edges_per_shard)
            if edges_per_shard
            else max(1, -(-self.graph.num_directed // max(1, n_shards)))
        )
        link = link_bytes_per_us or mesh_link_bytes_per_us()
        wire_bytes = (n_shards - 1) * rows * padded_cols * self.itemsize
        comm_us = wire_bytes / link
        # per-shard compute: the edge-bucket gather over the stage's padded
        # passive width plus this shard's share of the group's eMA work
        gather = edges * padded_cols
        ema = 0
        for q, j in self.plan.exec_groups[leader]:
            mplan = self.plan.counting_plans[q]
            msub = mplan.partition.subs[j]
            ema += rows * binom(mplan.k, msub.size) * binom(
                msub.size, mplan.partition.subs[msub.active].size
            )
        compute_us = (gather + ema) * WORK_ELEMENT_US
        if n_shards >= 2:
            comm_step = comm_us / (n_shards - 1)
            compute_step = compute_us / n_shards
            overlap = min(1.0, compute_step / comm_step) if comm_step > 0 else 1.0
        else:
            overlap = 0.0
        hidden_us = overlap * comm_us
        ring_cost_us = n_batches * n_shards * RING_STEP_OVERHEAD_US
        if forced in ("blocking", "pipelined"):
            mode = forced
            reason = f"forced {forced} (env/caller override)"
        elif n_shards < 2:
            mode = "blocking"
            reason = "single shard — nothing to overlap"
        elif hidden_us > ring_cost_us:
            mode = "pipelined"
            reason = (
                f"hidden {hidden_us:.1f}us > ring overhead {ring_cost_us:.1f}us"
            )
        else:
            mode = "blocking"
            reason = (
                f"hidden {hidden_us:.1f}us <= ring overhead {ring_cost_us:.1f}us"
            )
        return CommSchedule(
            stage=(p_idx, i),
            mode=mode,
            ring_steps=n_shards if mode == "pipelined" else 1,
            slice_rows=rows,
            slice_cols=cb,
            wire_bytes=int(wire_bytes),
            comm_us=comm_us,
            compute_us=compute_us,
            overlap_efficiency=overlap,
            reason=reason,
        )

    def mesh_comm_schedules(
        self,
        n_shards: int,
        *,
        column_batch: int,
        rows_per_shard: Optional[int] = None,
        edges_per_shard: Optional[int] = None,
        link_bytes_per_us: Optional[float] = None,
        forced: Optional[str] = None,
    ) -> "Dict[Tuple[int, int], CommSchedule]":
        """The full per-stage comm plan: one :class:`CommSchedule` per tree
        exec-group leader (the unit one passive sweep serves)."""
        return {
            leader: self.comm_schedule(
                leader,
                n_shards,
                column_batch=column_batch,
                rows_per_shard=rows_per_shard,
                edges_per_shard=edges_per_shard,
                link_bytes_per_us=link_bytes_per_us,
                forced=forced,
            )
            for leader in self.tree_group_leaders()
        }

    # -- bytes + chunk -------------------------------------------------------

    def bytes_per_coloring(
        self, transient_elements: int, resident_elements: int
    ) -> int:
        """Calibrated live bytes one coloring contributes to a chunk.

        The analytic element model times the store itemsize, corrected by
        the empirical fusion-slack factor (``slack < 1`` means the model
        under-predicts, so the effective figure grows).
        """
        raw = (transient_elements + resident_elements) * self.itemsize
        return int(math.ceil(raw / self.fusion_slack))

    def pick_chunk_size(
        self,
        bytes_per_coloring: int,
        memory_budget_bytes: int = DEFAULT_MEMORY_BUDGET_BYTES,
        max_chunk: int = MAX_CHUNK_SIZE,
    ) -> int:
        return pick_chunk_size(bytes_per_coloring, memory_budget_bytes, max_chunk)

    def describe(self) -> Dict:
        out = {
            "fusion_slack": self.fusion_slack,
            "itemsize": self.itemsize,
            "peak_columns": self.plan.peak_columns,
            "resident_elements": self.resident_elements(),
        }
        if getattr(self.plan, "has_bag_stages", False):
            out["peak_elements"] = self.plan.peak_elements(self.graph.n)
            out["max_bag_axes"] = self.plan.max_bag_axes
        return out

    # -- tuning candidate lattice --------------------------------------------

    def feasible_backends(self, platform: Optional[str] = None) -> "list[str]":
        """Local backends worth *probing* for this (graph, plan).

        Wider than the heuristic's single pick, narrower than "everything":
        backends whose geometry would be pathological on this graph (ELL
        padding blown up by a hub row, an ``n x n`` dense adjacency that
        dwarfs the DP state) are excluded so the tuner never compiles them.
        ``blocked`` is TPU-only — on CPU the Pallas kernel runs in
        interpret mode, which is a correctness path, not a candidate.
        """
        g = self.graph
        edges = max(g.num_directed, 1)
        out = ["edges"]
        # probe-feasibility bound is deliberately looser than the
        # heuristic's ELL_PAD_FACTOR pick threshold: measurement decides
        if g.n * max(g.max_degree(), 1) <= 8 * edges:
            out.append("ell")
        out.append("sell")
        if g.n <= 8192:  # n^2 adjacency: 256 MB fp32 at 8k vertices
            out.append("dense")
        if platform == "tpu":
            out.append("blocked")
        return out

    def sell_padded_slots(self) -> int:
        """Host-built SELL geometry (memoized — the lattice prices the
        ``sell`` target per exec group, the probe engines rebuild it)."""
        cached = getattr(self, "_sell_padded_slots", None)
        if cached is None:
            from repro.core.graph import build_sell  # local: cycle-free

            cached = build_sell(self.graph).padded_slots
            object.__setattr__(self, "_sell_padded_slots", cached)
        return cached

    def spmm_work_elements(self, target: str) -> int:
        """Gathered/reduced elements per passive DP column on ``target``
        (the backend-dependent half of a stage's work)."""
        g = self.graph
        edges = max(g.num_directed, 1)
        if target in ("edges", "custom"):
            return edges
        if target == "ell":
            return g.n * max(g.max_degree(), 1)
        if target == "sell":
            return self.sell_padded_slots()
        if target == "dense":
            # n^2 MACs at matmul throughput ~= n^2 / advantage gather-grade
            # element visits (same constant select_backend compares with)
            return max(1, g.n**2 // _dense_work_advantage())
        if target == "blocked":
            return edges
        raise ValueError(f"unknown work target {target!r}")

    def group_cost_us(
        self, leader, backend: str, column_batch: int
    ) -> float:
        """Raw (uncalibrated) predicted us for one exec group's sweep.

        One group = one passive column-batch sweep shared by every member
        stage: the backend's gather over ``C(k, m_p)`` passive columns,
        each member's eMA contraction (``n * n_out * n_splits`` FMAs,
        backend-independent), and a fixed dispatch cost per fused slice.
        """
        from repro.core.colorsets import binom  # local: cycle-free

        p_idx, i = leader
        cplan = self.plan.counting_plans[p_idx]
        sub = cplan.partition.subs[i]
        passive_cols = binom(cplan.k, cplan.partition.subs[sub.passive].size)
        gather = self.spmm_work_elements(backend) * passive_cols
        ema = 0
        for q, j in self.plan.exec_groups[leader]:
            mplan = self.plan.counting_plans[q]
            msub = mplan.partition.subs[j]
            m = msub.size
            m_a = mplan.partition.subs[msub.active].size
            ema += self.graph.n * binom(mplan.k, m) * binom(m, m_a)
        cb = max(1, min(int(column_batch), passive_cols))
        sweeps = math.ceil(passive_cols / cb)
        return (gather + ema) * WORK_ELEMENT_US + sweeps * SWEEP_OVERHEAD_US

    def tree_group_leaders(self) -> "list":
        """Exec-group leaders of *tree* stages — the addresses a mixed
        config can bind (bag programs run through the uniform default)."""
        return [
            leader
            for leader in sorted(self.plan.exec_groups)
            if self.plan.counting_plans[leader[0]].partition is not None
        ]

    def predict_config_us(
        self,
        config,
        *,
        chunk_size: int,
        calibration: Optional[Dict[str, float]] = None,
        mesh_shards: Optional[int] = None,
    ) -> "Tuple[float, float]":
        """``(calibrated_us, raw_us)`` per coloring for one
        :class:`~repro.tune.config.TuningConfig`.

        Calibration multiplies each group's cost by its backend's
        measured/predicted ratio; ``raw_us`` skips that (it is what new
        measurements are ratioed against, keeping calibration a fixed
        point).  Bag-stage plans price their bag ops into the default
        backend's share implicitly via the launch term only — the lattice
        still ranks, it just ranks on the tree groups it can rebind.

        ``default_backend == "mesh"`` configs route through the comm model
        (:meth:`predict_mesh_config_us`; ``mesh_shards`` supplies the ring
        size).
        """
        calibration = calibration or {}
        if config.default_backend == "mesh":
            return self.predict_mesh_config_us(
                config,
                chunk_size=chunk_size,
                n_shards=mesh_shards or 1,
                calibration=calibration,
            )
        bindings = config.bindings()
        cb = config.column_batch or self.pick_local_column_batch()
        raw = calibrated = LAUNCH_OVERHEAD_US / max(1, int(chunk_size))
        for leader in self.tree_group_leaders():
            backend = bindings.get(leader, config.default_backend)
            cost = self.group_cost_us(leader, backend, cb)
            raw += cost
            calibrated += cost * calibration.get(backend, 1.0)
        return calibrated, raw

    def predict_mesh_config_us(
        self,
        config,
        *,
        chunk_size: int,
        n_shards: int,
        calibration: Optional[Dict[str, float]] = None,
    ) -> "Tuple[float, float]":
        """``(calibrated_us, raw_us)`` per coloring for a mesh config.

        Per stage: per-shard compute plus the *visible* (un-hidden) wire
        time under the config's comm mode, plus the per-sweep dispatch and
        (pipelined) per-ring-step overheads — the figures the
        :meth:`comm_schedule` decision rule balances, summed instead of
        compared.
        """
        calibration = calibration or {}
        cb = config.column_batch or self.pick_mesh_column_batch()
        raw = LAUNCH_OVERHEAD_US / max(1, int(chunk_size))
        for leader in self.tree_group_leaders():
            sched = self.comm_schedule(
                leader, n_shards, column_batch=cb,
                forced=getattr(config, "mesh_comm", None),
            )
            per_slice = (
                max(0, n_shards - 1)
                * sched.slice_rows
                * sched.slice_cols
                * self.itemsize
            )
            n_batches = (
                max(1, round(sched.wire_bytes / per_slice)) if per_slice else 1
            )
            visible_comm = (
                sched.comm_us * (1.0 - sched.overlap_efficiency)
                if sched.ring_steps > 1
                else sched.comm_us
            )
            step_overhead = (
                n_batches * sched.ring_steps * RING_STEP_OVERHEAD_US
                if sched.ring_steps > 1
                else 0.0
            )
            raw += (
                sched.compute_us
                + visible_comm
                + n_batches * SWEEP_OVERHEAD_US
                + step_overhead
            )
        return raw * calibration.get("mesh", 1.0), raw

    def candidate_lattice(
        self,
        *,
        platform: Optional[str] = None,
        calibration: Optional[Dict[str, float]] = None,
        memory_budget_bytes: int = DEFAULT_MEMORY_BUDGET_BYTES,
        chunk_size: Optional[int] = None,
        include_mixed: bool = True,
        mesh_shards: Optional[int] = None,
    ) -> "list[RankedCandidate]":
        """Ranked tuning candidates, cheapest-predicted first.

        The cross product of memory budgets x feasible backends x column
        batches x chunk sizes, plus (``include_mixed``) one greedy mixed
        candidate per (budget, column batch) binding each exec group to
        its per-group-cheapest backend.  The budget axis sweeps the given
        budget and its half (floored at 1 MiB) — each candidate records
        the budget it was priced under
        (``TuningConfig.memory_budget_bytes``), so differently-budgeted
        winners never share an engine cache key.  With ``mesh_shards``
        (the tuner ran with a ``mesh=``), mesh candidates join the lattice
        with the comm mode (``blocking`` | ``pipelined``) as an extra
        axis, priced by the comm model.  The tuner measures the top-N of
        this list; everything else is pruned unseen — which is the whole
        point of keeping an analytic model around once measurements
        exist.
        """
        from repro.tune.config import TuningConfig  # local: cycle-free

        if calibration is None:
            calibration = load_backend_calibration()
        backends = self.feasible_backends(platform)
        resident = self.resident_elements()
        picked_cb = self.pick_local_column_batch()
        max_cb = max(1, self.plan.max_passive_columns)
        col_batches = sorted({
            min(4, max_cb), min(picked_cb, max_cb), min(64, max_cb)
        })
        budget = int(memory_budget_bytes)
        budgets = sorted({budget, max(budget // 2, 1 << 20)})
        leaders = self.tree_group_leaders()
        candidates = []
        seen = set()

        def _add(config):
            if config.key_fragment() in seen:
                return
            seen.add(config.key_fragment())
            calibrated, raw = self.predict_config_us(
                config,
                chunk_size=config.chunk_size,
                calibration=calibration,
                mesh_shards=mesh_shards,
            )
            candidates.append(
                RankedCandidate(config=config, predicted_us=calibrated, raw_us=raw)
            )

        for bud in budgets:
            for cb in col_batches:
                # per-BACKEND chunk sets: each backend is probed at the
                # chunk its own byte model picks under this budget (plus
                # the half), never at a chunk derived from another
                # backend's transient — cross-pollinated chunks used to
                # crowd the analytic pick out of the probed top-N
                chunks_by_backend = {}
                for b in backends:
                    if chunk_size:
                        chunks_by_backend[b] = {int(chunk_size)}
                        continue
                    per = self.bytes_per_coloring(
                        self.transient_elements(
                            b,
                            cb,
                            sell_padded_slots=(
                                self.sell_padded_slots() if b == "sell" else None
                            ),
                        ),
                        resident,
                    )
                    picked = self.pick_chunk_size(per, bud)
                    chunks_by_backend[b] = {picked, max(1, picked // 2)}
                for b in backends:
                    for chunk in sorted(chunks_by_backend[b]):
                        _add(TuningConfig(
                            default_backend=b, column_batch=cb, chunk_size=chunk,
                            memory_budget_bytes=bud,
                        ))
                if include_mixed and len(backends) > 1 and leaders:
                    greedy = tuple(
                        (
                            leader,
                            min(
                                backends,
                                key=lambda b: self.group_cost_us(leader, b, cb)
                                * calibration.get(b, 1.0),
                            ),
                        )
                        for leader in leaders
                    )
                    names = {b for _, b in greedy}
                    if len(names) > 1:
                        # default backend serves bag ops + plain spmm: the
                        # cheapest gather-per-column backend among the bound
                        default = min(
                            names, key=lambda b: self.spmm_work_elements(b)
                        )
                        for chunk in sorted(chunks_by_backend[default]):
                            _add(TuningConfig(
                                default_backend=default,
                                group_backends=greedy,
                                column_batch=cb,
                                chunk_size=chunk,
                                memory_budget_bytes=bud,
                            ))
            if mesh_shards:
                # mesh candidates: the comm mode is the swept axis; chunk
                # comes from the resident footprint (the dominant per-shard
                # term the budget bounds)
                mesh_cb = self.pick_mesh_column_batch()
                per = self.bytes_per_coloring(0, resident)
                picked = (
                    int(chunk_size)
                    if chunk_size
                    else self.pick_chunk_size(per, bud)
                )
                for comm in ("blocking", "pipelined"):
                    _add(TuningConfig(
                        default_backend="mesh",
                        column_batch=mesh_cb,
                        chunk_size=picked,
                        memory_budget_bytes=bud,
                        mesh_comm=comm,
                    ))
        candidates.sort(key=lambda c: (c.predicted_us, repr(c.config.key_fragment())))
        # two budgets that land on the same (backend, groups, cb, chunk,
        # comm) build the same engine — measuring both burns a probe slot
        # for zero information, so keep only the best-ranked of each
        unique, seen_runtime = [], set()
        for cand in candidates:
            cfg = cand.config
            runtime = (
                cfg.default_backend, cfg.group_backends, cfg.column_batch,
                cfg.chunk_size, cfg.mesh_comm,
            )
            if runtime in seen_runtime:
                continue
            seen_runtime.add(runtime)
            unique.append(cand)
        return unique
