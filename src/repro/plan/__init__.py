"""repro.plan: backend-agnostic compilation plans for the counting pipeline.

This package is the first layer of the engine's three-layer pipeline
(plan -> cost -> exec):

* :mod:`repro.plan.ir` — the :class:`TemplatePlan` intermediate
  representation: the complete static DP schedule for a set of same-``k``
  templates (stages with canonical-form sharing, shared-passive execution
  groups, the liveness schedule, per-stage width annotations), built once
  by the pure planner :func:`build_template_plan`.  Every execution
  backend — local, SELL, blocked Pallas, mesh — consumes a
  ``TemplatePlan`` instead of re-deriving schedules.
* :mod:`repro.plan.cost` — the unified resource model
  (:class:`CostModel`): peak live columns, per-coloring byte footprints,
  and chunk / column-batch picking for every execution target, calibrated
  by the empirical fusion-slack factor measured from committed
  ``memory_model`` bench rows.

``python -m repro.plan <template> [--graph ...]`` pretty-prints a plan
(stage schedule, exec groups, liveness peak, predicted bytes).
"""

# Imported first so that entering the package directly (e.g. the CLI or a
# bare ``import repro.plan``) finishes loading the core submodules this
# package reads before ``.ir``/``.cost`` resolve them — repro.core.engine
# itself imports repro.plan, so the two sides meet in the middle.  The
# assignment keeps the anchor visible to linters (pyflakes has no noqa).
import repro.core

# `repro` (not `repro.core`): mid-cycle the submodule is in sys.modules
# but not yet bound as an attribute on the parent package
_CYCLE_ANCHOR = repro

from .cost import (
    DEFAULT_MEMORY_BUDGET_BYTES,
    LOCAL_COLUMN_BATCH,
    MAX_CHUNK_SIZE,
    MESH_COLUMN_BATCH,
    CostModel,
    fusion_slack_factor,
    load_fusion_slack,
    pick_chunk_size,
)
from .ir import (
    PlanStage,
    TemplatePlan,
    build_template_plan,
    template_set_canons,
)

__all__ = [
    "PlanStage",
    "TemplatePlan",
    "build_template_plan",
    "template_set_canons",
    "CostModel",
    "load_fusion_slack",
    "fusion_slack_factor",
    "pick_chunk_size",
    "DEFAULT_MEMORY_BUDGET_BYTES",
    "MAX_CHUNK_SIZE",
    "LOCAL_COLUMN_BATCH",
    "MESH_COLUMN_BATCH",
]
