"""The TemplatePlan IR: one backend-agnostic compilation of a template set.

A :class:`TemplatePlan` is everything about a counting run that can be
decided *before* touching a graph or a device: the shared multi-template DP
schedule (stages de-duplicated by rooted canonical form), the
shared-passive execution groups, the liveness schedule that lets executors
free DP states at their last read, and per-stage column-width annotations.
It is built once per template set by the pure planner
:func:`build_template_plan` and consumed unchanged by every execution
backend (:mod:`repro.exec`) and by the cost model (:mod:`repro.plan.cost`).

Two plans with equal :meth:`TemplatePlan.schedule_key` compile to the same
programs — the key is the template half of
:func:`repro.core.engine.engine_cache_key`, so **plan equality implies
cache-key equality** (a property test in ``tests/test_plan.py`` pins this).

Position numbering (shared with the liveness schedule): the schedule walks
each plan's sub-templates in topological order, skipping canonical forms
already executed by an earlier plan; every *first occurrence* takes one
position, and each plan's root read takes one more.  ``free_at[pos]`` lists
the canonical states that are dead after position ``pos``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from repro.core.colorsets import binom
from repro.core.counting import (
    CountingPlan,
    build_counting_plan,
    liveness_peak_columns,
    liveness_peak_elements,
    schedule_liveness,
)
from repro.core.templates import (
    Template,
    build_bag_program,
    partition_template,
    sub_template_canonical,
)

__all__ = [
    "PlanStage",
    "TemplatePlan",
    "build_template_plan",
    "template_canon_sequence",
    "template_set_canons",
]


def template_canon_sequence(template: Template) -> Tuple[str, ...]:
    """Canonical form per DP stage of one template's default compilation.

    Trees: the rooted AHU canon of every partition sub-template.  Non-trees:
    the bag-state canon of every bag-program op.  Matches the per-stage
    canons :func:`build_template_plan` derives for default plans.
    """
    if template.is_tree:
        return tuple(
            sub_template_canonical(template, sub.vertices, sub.root)
            for sub in partition_template(template).subs
        )
    return tuple(op.canon for op in build_bag_program(template).ops)


def template_set_canons(
    templates: Sequence[Template],
) -> Tuple[Tuple[str, ...], ...]:
    """Per-template tuple of canonical forms of the DP stages.

    This is the template half of the engine cache key: two template sets
    with equal canon tuples produce identical DP schedules (same stages,
    same split tables, same sharing), so a compiled engine built for one
    serves the other.  Computable without building plans or split tables.
    Covers both families — tree canons are AHU strings, bag canons carry a
    ``"bag:"`` prefix, so the two can never alias.
    """
    return tuple(template_canon_sequence(t) for t in templates)


@dataclass(frozen=True)
class PlanStage:
    """One first-occurrence DP stage in the shared schedule.

    ``(plan_idx, sub_idx)`` addresses the stage in the per-template
    :class:`~repro.core.counting.CountingPlan`; ``position`` is its slot in
    the shared schedule (the key into :attr:`TemplatePlan.free_at`).  Width
    annotations are in M-matrix *columns* (``binom(k, size)``); leaves have
    no children, no table, and width ``k``.
    """

    plan_idx: int
    sub_idx: int
    position: int
    canon: str
    is_leaf: bool
    size: int
    columns: int
    active_canon: Optional[str] = None
    passive_canon: Optional[str] = None
    active_columns: int = 0
    passive_columns: int = 0
    table_key: Optional[Tuple[int, int, int]] = None  # (k, m, m_a)
    # Bag-stage annotations (tree stages leave these at their defaults, so
    # tree-only plans are byte-identical to the pre-bag IR):
    bag_kind: Optional[str] = None  # "leaf" | "extend" | "forget" | "join"
    bag_axes: Tuple[int, ...] = ()
    input_canons: Tuple[str, ...] = ()
    join_table_key: Optional[Tuple[int, int, int, int]] = None  # (k, m1, m2, overlap)

    @property
    def is_bag(self) -> bool:
        return self.bag_kind is not None

    @property
    def stage_columns(self) -> int:
        """Columns this stage holds live at once: children + output (the
        fused Pallas kernel's per-stage staging width)."""
        return self.columns + self.active_columns + self.passive_columns


@dataclass(frozen=True, eq=False)
class TemplatePlan:
    """The complete static schedule for one set of same-``k`` templates.

    Field reference (see ``docs/planning.md`` for the narrative):

    * ``k`` / ``templates`` — the template set (all share one ``k``).
    * ``counting_plans`` — per-template stage order + split tables
      (:class:`~repro.core.counting.CountingPlan`).
    * ``canons`` — per plan, per sub-template: the rooted AHU canonical
      form.  Equal strings share ONE DP state across the whole set.
    * ``stages`` — the shared schedule: every canonical form's first
      occurrence, in execution order, with width annotations.
    * ``free_at`` — liveness: position -> canonical states dead after it
      (the fused pipeline's schedule — no aggregate products exist).
    * ``free_at_products`` — the same schedule when memoized SpMM products
      are also tracked (the mesh backend's loop/vectorized eMA modes);
      product keys are ``("prod", canon)`` tuples.
    * ``exec_groups`` — shared-passive execution groups: leader
      ``(plan_idx, sub_idx)`` -> members (leader first).  All members read
      the same passive canonical form and their actives are live before
      the leader, so one passive column-batch sweep serves the group.
    * ``peak_columns`` — the liveness-aware peak of live M columns per
      coloring (the cost model's resident figure).
    * ``max_passive_columns`` / ``max_stage_columns`` — widest passive
      state / widest single stage (column-batch and Pallas staging bounds).

    Equality is *schedule identity*: two plans compare equal iff their
    ``(k, canons)`` agree — the invariant that makes plan equality imply
    engine-cache-key equality.
    """

    k: int
    templates: Tuple[Template, ...]
    counting_plans: Tuple[CountingPlan, ...]
    canons: Tuple[Tuple[str, ...], ...]
    stages: Tuple[PlanStage, ...]
    free_at: Mapping[int, Tuple[str, ...]]
    free_at_products: Mapping[int, Tuple] = field(repr=False)
    exec_groups: Mapping[Tuple[int, int], Tuple[Tuple[int, int], ...]]
    peak_columns: int
    max_passive_columns: int
    max_stage_columns: int
    # Bag-family annotations (defaults = the tree-only values, so tree-only
    # plans are unchanged by the generalization):
    has_bag_stages: bool = False
    max_bag_axes: int = 1
    decomposition_widths: Tuple[Optional[int], ...] = ()

    # -- identity ------------------------------------------------------------

    def schedule_key(self) -> Tuple:
        """Hashable schedule identity — the template half of the engine
        cache key.  Everything else in the IR derives deterministically
        from it."""
        return (self.k, self.canons)

    def __eq__(self, other) -> bool:
        if not isinstance(other, TemplatePlan):
            return NotImplemented
        return self.schedule_key() == other.schedule_key()

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return eq if eq is NotImplemented else not eq

    def __hash__(self) -> int:
        return hash(self.schedule_key())

    # -- derived views -------------------------------------------------------

    @property
    def num_templates(self) -> int:
        return len(self.templates)

    @property
    def num_positions(self) -> int:
        """Schedule length: first-occurrence stages + one root read per
        plan (the domain of ``free_at`` keys)."""
        return len(self.stages) + len(self.counting_plans)

    def stage_at(self, plan_idx: int, sub_idx: int) -> Optional[PlanStage]:
        """The first-occurrence stage addressed ``(plan_idx, sub_idx)``
        (``None`` when that sub is a duplicate of an earlier canon)."""
        for s in self.stages:
            if (s.plan_idx, s.sub_idx) == (plan_idx, sub_idx):
                return s
        return None

    def liveness(self, track_products: bool = False) -> Mapping[int, Tuple]:
        """The liveness schedule an executor should free against."""
        return self.free_at_products if track_products else self.free_at

    def padded_peak_columns(self, pad_unit: int, track_products: bool = False) -> int:
        """Liveness peak with every state's columns padded up to
        ``pad_unit`` (the mesh backend pads to its all-gather batch)."""
        return liveness_peak_columns(
            self.counting_plans,
            self.canons,
            pad_unit=pad_unit,
            track_products=track_products,
        )

    def peak_elements(self, n: int) -> int:
        """Liveness peak of live DP-state *elements* per coloring on an
        ``n``-vertex graph.  For tree-only plans this is exactly
        ``n * peak_columns``; bag states contribute ``n**axes * columns``."""
        return liveness_peak_elements(self.counting_plans, self.canons, n)

    def table_keys(self) -> Tuple[Tuple[int, int, int], ...]:
        """Distinct split-table identities ``(k, m, m_a)`` the plan needs."""
        seen: List[Tuple[int, int, int]] = []
        for s in self.stages:
            if s.table_key is not None and s.table_key not in seen:
                seen.append(s.table_key)
        return tuple(seen)

    def join_table_keys(self) -> Tuple[Tuple[int, int, int, int], ...]:
        """Distinct union-table identities ``(k, m1, m2, overlap)`` needed
        by bag-join stages (empty for tree-only plans)."""
        seen: List[Tuple[int, int, int, int]] = []
        for s in self.stages:
            if s.join_table_key is not None and s.join_table_key not in seen:
                seen.append(s.join_table_key)
        return tuple(seen)

    def describe(self) -> Dict:
        """Structured summary (the CLI and ``CountingEngine.describe()``
        both render from this)."""
        out = {
            "k": self.k,
            "templates": [t.name for t in self.templates],
            "stages": len(self.stages),
            "positions": self.num_positions,
            "unique_canons": len({c for cs in self.canons for c in cs}),
            "total_subs": sum(len(cs) for cs in self.canons),
            "shared_passive_groups": sum(
                1 for m in self.exec_groups.values() if len(m) > 1
            ),
            "peak_columns": self.peak_columns,
            "naive_peak_columns": sum(p.peak_columns() for p in self.counting_plans),
            "max_passive_columns": self.max_passive_columns,
            "max_stage_columns": self.max_stage_columns,
            "table_keys": [list(tk) for tk in self.table_keys()],
        }
        if self.has_bag_stages:
            out["bag_stages"] = sum(1 for s in self.stages if s.is_bag)
            out["max_bag_axes"] = self.max_bag_axes
            out["decomposition_widths"] = {
                t.name: w
                for t, w in zip(self.templates, self.decomposition_widths)
                if w is not None
            }
            out["join_table_keys"] = [list(tk) for tk in self.join_table_keys()]
        return out


def _build_shared_passive_groups(
    counting_plans: Sequence[CountingPlan],
    canons: Sequence[Sequence[str]],
) -> Dict[Tuple[int, int], Tuple[Tuple[int, int], ...]]:
    """Static schedule of shared-passive stage groups.

    Walks the first-occurrence stages in execution order; each non-leaf
    stage either leads a group or was claimed by an earlier leader.  A
    later stage joins a leader's group when (a) it reads the same passive
    canonical form and (b) its active state is already computed before the
    leader's position (group members execute at the leader's position, so
    inputs produced between leader and member cannot be used).  Pulling a
    member earlier only moves its reads/writes forward, so the sequential
    liveness schedule stays valid: nothing a group reads can have been
    freed yet, and outputs are never freed before their sequential last
    read.

    Returns ``leader (plan_idx, stage_idx) -> members`` (leader first;
    singleton groups for unshared stages).
    """
    seq: List[Tuple[int, int, str]] = []  # first occurrences, exec order
    seen = set()
    for p_idx, plan in enumerate(counting_plans):
        n_stages = (
            len(plan.partition.subs)
            if plan.partition is not None
            else len(plan.bag_program.ops)
        )
        for i in range(n_stages):
            c = canons[p_idx][i]
            if c in seen:
                continue
            seen.add(c)
            seq.append((p_idx, i, c))
    # canons computed strictly before each seq position
    avail_before: List[frozenset] = []
    acc: set = set()
    for _, _, c in seq:
        avail_before.append(frozenset(acc))
        acc.add(c)
    groups: Dict[Tuple[int, int], Tuple[Tuple[int, int], ...]] = {}
    member: set = set()
    for idx, (p_idx, i, _) in enumerate(seq):
        if counting_plans[p_idx].partition is None:
            # Bag ops never lead a shared-passive group (their SpMM runs on
            # one axis of a multi-axis state, not a passive column sweep);
            # they still occupy `seq` so their canons gate availability.
            continue
        sub = counting_plans[p_idx].partition.subs[i]
        if sub.is_leaf or (p_idx, i) in member:
            continue
        passive_canon = canons[p_idx][sub.passive]
        members = [(p_idx, i)]
        for jdx in range(idx + 1, len(seq)):
            q, j, _ = seq[jdx]
            if counting_plans[q].partition is None:
                continue
            sub2 = counting_plans[q].partition.subs[j]
            if sub2.is_leaf or (q, j) in member:
                continue
            if canons[q][sub2.passive] != passive_canon:
                continue
            if canons[q][sub2.active] not in avail_before[idx]:
                continue
            members.append((q, j))
            member.add((q, j))
        groups[(p_idx, i)] = tuple(members)
    return groups


def build_template_plan(
    templates: Union[Template, Sequence[Template]],
    plans: Optional[Sequence[CountingPlan]] = None,
) -> TemplatePlan:
    """The pure planner: template set -> :class:`TemplatePlan`.

    Builds (or adopts) one :class:`~repro.core.counting.CountingPlan` per
    template, derives the canonical-form sharing, the first-occurrence
    schedule with width annotations, both liveness schedules, and the
    shared-passive execution groups.  No graph, no device, no side effects
    — the same template set always yields an equal plan.
    """
    if isinstance(templates, Template):
        templates = [templates]
    templates = tuple(templates)
    if not templates:
        raise ValueError("build_template_plan needs at least one template")
    ks = {t.k for t in templates}
    if len(ks) != 1:
        raise ValueError(
            f"all templates must share one k to share colorings, got k={sorted(ks)}"
        )
    k = ks.pop()

    if plans is None:
        counting_plans = tuple(build_counting_plan(t) for t in templates)
    else:
        if len(plans) != len(templates):
            raise ValueError("plans must align with templates")
        counting_plans = tuple(plans)

    canons: Tuple[Tuple[str, ...], ...] = tuple(
        plan.stage_canons() for plan in counting_plans
    )

    # first-occurrence schedule with width annotations (positions shared
    # with schedule_liveness: stages and root reads both advance `pos`)
    stages: List[PlanStage] = []
    executed = set()
    max_passive = 1
    max_stage = 1
    max_bag_axes = 1
    pos = 0
    for p_idx, plan in enumerate(counting_plans):
        pc = canons[p_idx]
        if plan.partition is not None:
            for i, sub in enumerate(plan.partition.subs):
                if pc[i] in executed:
                    continue
                executed.add(pc[i])
                if sub.is_leaf:
                    stages.append(
                        PlanStage(
                            plan_idx=p_idx,
                            sub_idx=i,
                            position=pos,
                            canon=pc[i],
                            is_leaf=True,
                            size=1,
                            columns=k,
                        )
                    )
                else:
                    active = plan.partition.subs[sub.active]
                    passive = plan.partition.subs[sub.passive]
                    c_a = binom(k, active.size)
                    c_p = binom(k, passive.size)
                    stage = PlanStage(
                        plan_idx=p_idx,
                        sub_idx=i,
                        position=pos,
                        canon=pc[i],
                        is_leaf=False,
                        size=sub.size,
                        columns=binom(k, sub.size),
                        active_canon=pc[sub.active],
                        passive_canon=pc[sub.passive],
                        active_columns=c_a,
                        passive_columns=c_p,
                        table_key=(k, sub.size, active.size),
                    )
                    stages.append(stage)
                    max_passive = max(max_passive, c_p)
                    max_stage = max(max_stage, stage.stage_columns)
                pos += 1
            pos += 1  # the plan's root read
        else:
            prog = plan.bag_program
            for i, op in enumerate(prog.ops):
                if pc[i] in executed:
                    continue
                executed.add(pc[i])
                table_key = (k, op.m, 1) if op.kind == "extend" else None
                join_key = None
                if op.kind == "join":
                    o1, o2 = prog.ops[op.inputs[0]], prog.ops[op.inputs[1]]
                    overlap = len(set(o1.covered) & set(o2.covered))
                    join_key = (k, o1.m, o2.m, overlap)
                stages.append(
                    PlanStage(
                        plan_idx=p_idx,
                        sub_idx=i,
                        position=pos,
                        canon=pc[i],
                        is_leaf=op.kind == "leaf",
                        size=op.m,
                        columns=k if op.kind == "leaf" else binom(k, op.m),
                        table_key=table_key,
                        bag_kind=op.kind,
                        bag_axes=op.axes,
                        input_canons=tuple(pc[j] for j in op.inputs),
                        join_table_key=join_key,
                    )
                )
                max_bag_axes = max(
                    max_bag_axes, len(op.axes) + len(op.forget_vertices)
                )
                pos += 1
            pos += 1  # the plan's root read

    free_at = {
        p: tuple(keys)
        for p, keys in schedule_liveness(counting_plans, canons).items()
    }
    free_at_products = {
        p: tuple(keys)
        for p, keys in schedule_liveness(
            counting_plans, canons, track_products=True
        ).items()
    }

    return TemplatePlan(
        k=k,
        templates=templates,
        counting_plans=counting_plans,
        canons=canons,
        stages=tuple(stages),
        free_at=free_at,
        free_at_products=free_at_products,
        exec_groups=_build_shared_passive_groups(counting_plans, canons),
        peak_columns=liveness_peak_columns(counting_plans, canons),
        max_passive_columns=max_passive,
        max_stage_columns=max_stage,
        has_bag_stages=any(p.partition is None for p in counting_plans),
        max_bag_axes=max_bag_axes,
        decomposition_widths=tuple(
            p.bag_program.width if p.partition is None else None
            for p in counting_plans
        ),
    )
