"""Elastic scaling: rebuild a smaller/larger mesh and re-shard state.

On a real deployment a failed host drops out of ``jax.devices()`` after the
coordinator barrier; here we model the decision logic + re-sharding so the
policy is testable: ``plan_elastic_mesh`` picks the largest valid mesh shape
from the surviving device count, and ``reshard_tree`` moves a host-resident
checkpointed state onto the new mesh (restore-based elasticity — the
recommended large-fleet pattern: checkpoint, shrink, restore)."""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["plan_elastic_mesh", "reshard_tree", "survivors_after_failure"]


def survivors_after_failure(devices: Sequence, failed_indices: Sequence[int]) -> list:
    failed = set(failed_indices)
    return [d for i, d in enumerate(devices) if i not in failed]


def plan_elastic_mesh(
    n_devices: int,
    axis_names: Tuple[str, ...] = ("data", "model"),
    model_parallel: int = 2,
) -> Tuple[int, ...]:
    """Largest (data, model) shape with ``model_parallel`` fixed and data as
    large as the surviving devices allow (drops stragglers to a power-friendly
    count).  Raises if fewer than one model-parallel group survives."""
    if n_devices < model_parallel:
        raise ValueError(f"{n_devices} devices cannot host model_parallel={model_parallel}")
    data = n_devices // model_parallel
    return (data, model_parallel)


def reshard_tree(tree, mesh: Mesh, pspecs) -> object:
    """Place a host (numpy) pytree onto ``mesh`` with the given PartitionSpecs."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, pspecs)
