"""Optimizers (AdamW, Adafactor), gradient clipping, LR schedules.

Self-contained (no optax): ``init(params) -> state``, ``update(grads, state,
params, lr) -> (new_params, new_state)``.  All states are pytrees matching
``params`` — they shard with the same PartitionSpecs (optimizer-state
sharding comes free).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "clip_by_global_norm",
    "cosine_schedule",
    "linear_warmup_cosine",
]


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(mu=zeros, nu=jax.tree.map(jnp.zeros_like, params), count=jnp.zeros((), jnp.int32))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, AdamWState]:
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1**cf
    bc2 = 1.0 - b2**cf

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

    def step(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

    new_params = jax.tree.map(step, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count)


class AdafactorState(NamedTuple):
    row: Any   # row second-moment (or full for <2D tensors)
    col: Any
    count: jnp.ndarray


def adafactor_init(params) -> AdafactorState:
    def rows(p):
        return jnp.zeros(p.shape[:-1], p.dtype) if p.ndim >= 2 else jnp.zeros_like(p)

    def cols(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], p.dtype) if p.ndim >= 2 else jnp.zeros((), p.dtype)

    return AdafactorState(
        row=jax.tree.map(rows, params), col=jax.tree.map(cols, params), count=jnp.zeros((), jnp.int32)
    )


def adafactor_update(
    grads, state: AdafactorState, params, lr, decay: float = 0.8, eps: float = 1e-30
):
    """Factored second-moment (Shazeer & Stern 2018) — O(n+m) state per (n,m)
    matrix instead of O(nm); the memory-saving default for huge models."""
    count = state.count + 1
    beta = 1.0 - count.astype(jnp.float32) ** -decay

    def upd(p, g, r, c):
        if p.ndim >= 2:
            r2 = beta * r + (1 - beta) * (g * g).mean(-1)
            c2 = beta * c + (1 - beta) * (g * g).mean(-2)
            denom = jnp.sqrt(
                r2[..., :, None] * c2[..., None, :] / jnp.maximum(r2.mean(-1)[..., None, None], eps) + eps
            )
            return p - lr * g / denom, r2, c2
        r2 = beta * r + (1 - beta) * g * g
        return p - lr * g / (jnp.sqrt(r2) + 1e-8), r2, c

    out = jax.tree.map(upd, params, grads, state.row, state.col)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_row = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_col = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    return new_params, AdafactorState(row=new_row, col=new_col, count=count)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1) -> Callable:
    def lr(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(np.pi * frac)))

    return lr


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int, min_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)

    def lr(step):
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return lr
