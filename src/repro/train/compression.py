"""Gradient compression for cross-pod all-reduce.

Two codecs with **error feedback** (the residual of the lossy round is added
back into the next step's gradient, keeping convergence unbiased in the
long run — Seide et al. 2014 / Karimireddy et al. 2019):

* ``int8``: per-tensor symmetric quantization; 4x wire-size reduction.
* ``topk``: keep the largest |g| fraction per tensor (sparse deltas).

``compressed_psum`` wires a codec around ``jax.lax.psum`` inside shard_map:
quantize -> sum int32 -> dequantize (int8 path sums in int32 so the reduce
itself stays lossless after quantization).
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "topk_sparsify",
    "compress_with_feedback",
    "compressed_psum",
]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_sparsify(x: jnp.ndarray, frac: float = 0.05) -> jnp.ndarray:
    """Zero all but the top-|x| fraction (dense mask form — the wire format
    on a real fabric would be (indices, values))."""
    flat = jnp.abs(x.reshape(-1))
    k = max(int(flat.shape[0] * frac), 1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compress_with_feedback(grad: jnp.ndarray, residual: jnp.ndarray, codec: str = "int8", **kw):
    """Returns (decompressed_grad, new_residual)."""
    g = grad + residual
    if codec == "int8":
        q, scale = quantize_int8(g)
        dec = dequantize_int8(q, scale)
    elif codec == "topk":
        dec = topk_sparsify(g, **kw)
    else:
        raise ValueError(f"unknown codec {codec!r}")
    return dec, g - dec


def compressed_psum(grad: jnp.ndarray, axis_names, residual: jnp.ndarray):
    """int8-quantized cross-replica mean with error feedback.

    Quantizes locally, sums the int8 payload in int32 (lossless reduce),
    dequantizes with a max-combined scale.  Wire bytes: 1/4 of fp32 + one
    scalar scale psum.
    """
    g = grad + residual
    scale = jax.lax.pmax(jnp.max(jnp.abs(g)) / 127.0 + 1e-12, axis_names)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_names)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
    mean = total.astype(jnp.float32) * scale / n
    local_dec = q.astype(jnp.float32) * scale
    return mean, g - local_dec
