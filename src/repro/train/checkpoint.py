"""Sharded, atomic, async checkpointing.

Layout: ``<dir>/step_<N>/`` with one ``.npz`` per host process (here: one)
plus a manifest.  Writes go to a temp dir + atomic rename so a crash mid-write
never corrupts the latest checkpoint; ``restore_latest`` skips incomplete
step dirs.  ``AsyncCheckpointer`` moves the host transfer + write off the
training thread (device->host copy happens synchronously under jit boundary
semantics; serialization happens in the background).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "restore_latest", "AsyncCheckpointer"]

_MANIFEST = "manifest.json"


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    return arrays, treedef


def save_checkpoint(directory: str, step: int, tree, extra: Optional[Dict] = None) -> str:
    """Atomic write of a pytree checkpoint; returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays, _ = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "n_leaves": len(arrays), "time": time.time(), "extra": extra or {}}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_checkpoint(path: str, tree_like) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = jax.tree.flatten(tree_like)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, model has {len(leaves)}"
        )
    restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for got, want in zip(restored, leaves):
        if got.shape != np.shape(want):
            raise ValueError(f"shape mismatch: checkpoint {got.shape} vs model {np.shape(want)}")
    return jax.tree.unflatten(treedef, restored), manifest


def restore_latest(directory: str, tree_like) -> Optional[Tuple[Any, Dict]]:
    """Most recent *complete* checkpoint, or None."""
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(directory, d, _MANIFEST))
    )
    if not steps:
        return None
    return restore_checkpoint(os.path.join(directory, steps[-1]), tree_like)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with at-most-one in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # device->host before async

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.directory) if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)
