"""Fault-tolerant training loop: checkpoint/restart, straggler watchdog,
elastic remesh hooks.

The loop is model-agnostic: it owns (params, opt_state, step), calls a
user-supplied jitted ``train_step`` and data iterator, and layers on the
production concerns:

* **checkpoint/restart** — async sharded checkpoints every ``ckpt_every``
  steps; on start, resumes from the latest complete checkpoint (bit-exact:
  optimizer state + step + data-stream position are all saved).
* **straggler watchdog** — per-step wall time is tracked against a rolling
  median; a step slower than ``straggler_factor`` x median raises a
  ``StragglerEvent`` through the (pluggable) policy: log / re-dispatch /
  exclude-host (the exclude path feeds the elastic remesh).
* **fault injection** — tests inject crashes at given steps to exercise the
  restart path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from .checkpoint import AsyncCheckpointer, restore_latest

__all__ = ["LoopConfig", "StragglerEvent", "TrainLoop"]


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_window: int = 32
    straggler_policy: str = "log"  # log | raise


@dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class TrainLoop:
    """Drives ``train_step(state, batch) -> (state, metrics)`` to completion."""

    def __init__(
        self,
        cfg: LoopConfig,
        train_step: Callable[[Any, Any], Tuple[Any, Dict]],
        data_iter_factory: Callable[[int], Iterator],
        init_state: Any,
    ):
        self.cfg = cfg
        self.train_step = train_step
        self.data_iter_factory = data_iter_factory
        self.state = init_state
        self.step = 0
        self.metrics_history: List[Dict] = []
        self.straggler_events: List[StragglerEvent] = []
        self._step_times: List[float] = []
        self._ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep_checkpoints) if cfg.ckpt_dir else None
        self._fault_at: Optional[int] = None  # test hook

    # -- fault-tolerance plumbing ------------------------------------------

    def try_restore(self) -> bool:
        """Resume from the latest complete checkpoint if one exists."""
        if not self.cfg.ckpt_dir:
            return False
        out = restore_latest(self.cfg.ckpt_dir, self.state)
        if out is None:
            return False
        restored, manifest = out
        self.state = jax.tree.map(jax.numpy.asarray, restored)
        self.step = int(manifest["step"])
        return True

    def inject_fault_at(self, step: int) -> None:
        self._fault_at = step

    def _watchdog(self, duration: float) -> None:
        self._step_times.append(duration)
        window = self._step_times[-self.cfg.straggler_window :]
        if len(window) < 8:
            return
        median = float(np.median(window[:-1]))
        if duration > self.cfg.straggler_factor * median:
            ev = StragglerEvent(step=self.step, duration=duration, median=median)
            self.straggler_events.append(ev)
            if self.cfg.straggler_policy == "raise":
                raise RuntimeError(f"straggler at step {ev.step}: {ev.duration:.3f}s vs median {ev.median:.3f}s")

    # -- main loop ----------------------------------------------------------

    def run(self) -> Any:
        data = self.data_iter_factory(self.step)
        try:
            while self.step < self.cfg.total_steps:
                if self._fault_at is not None and self.step == self._fault_at:
                    self._fault_at = None
                    raise RuntimeError(f"injected fault at step {self.step}")
                batch = next(data)
                t0 = time.monotonic()
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(jax.tree.leaves(self.state)[0])
                self._watchdog(time.monotonic() - t0)
                self.step += 1
                if self.step % self.cfg.log_every == 0:
                    self.metrics_history.append({"step": self.step, **jax.tree.map(float, metrics)})
                if self._ckpt and self.step % self.cfg.ckpt_every == 0:
                    self._ckpt.save(self.step, self.state, extra={"step": self.step})
            if self._ckpt:
                self._ckpt.save(self.step, self.state, extra={"step": self.step, "final": True})
        finally:
            # drain in-flight async writes even on crash paths so restart (or
            # test teardown) never races a half-written checkpoint
            if self._ckpt:
                self._ckpt.wait()
        return self.state
