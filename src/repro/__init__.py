"""repro: SubGraph2Vec (vectorized tree subgraph counting) as a JAX framework."""

__version__ = "1.0.0"
