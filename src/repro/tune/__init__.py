"""repro.tune: measurement-driven autotuning for the counting engine.

The config space the analytic :class:`~repro.plan.cost.CostModel` only
*guesses* at — per-exec-group backend (mixed backends within one plan
included), fused-slice column batch, coloring chunk size — searched by
on-device measurement and persisted per ``(graph signature, plan canons,
device kind)``:

* :mod:`repro.tune.config` — :class:`TuningConfig`, the frozen value
  object engines bind (``CountingEngine(..., tuning=cfg)``);
* :mod:`repro.tune.cache` — the versioned JSON :class:`TuningCache`
  (default file: repo-root ``TUNED_counting.json``, override with
  ``REPRO_TUNE_CACHE``) plus the memoized ``consult`` read path backend
  resolution uses;
* :mod:`repro.tune.search` — :func:`tune`: rank the candidate lattice,
  measure the top-N with ``count_keys_chunk``-shaped probes, persist the
  winner and per-backend calibration ratios;
* ``python -m repro.tune`` — the CLI (measured-vs-predicted table).

Serve-time behavior is governed by ``REPRO_TUNE`` (``off`` | ``cached`` |
``full``) and always loses to an explicit ``backend=`` argument or the
``REPRO_ENGINE_BACKEND`` env override — see
:func:`repro.exec.select.resolve_backend_config`.
"""

from .cache import (
    TUNE_CACHE_ENV_VAR,
    TuningCache,
    canons_digest,
    consult,
    default_cache_path,
    device_kind,
    entry_key,
    invalidate_entry,
    load_calibration,
)
from .config import TUNING_SCHEMA_VERSION, TuningConfig
from .search import MeasuredCandidate, TuneResult, measure_engine_us, tune

__all__ = [
    "TuningConfig",
    "TuningCache",
    "TuneResult",
    "MeasuredCandidate",
    "tune",
    "measure_engine_us",
    "consult",
    "load_calibration",
    "invalidate_entry",
    "canons_digest",
    "entry_key",
    "device_kind",
    "default_cache_path",
    "TUNE_CACHE_ENV_VAR",
    "TUNING_SCHEMA_VERSION",
]
