"""TuningCache: versioned JSON persistence of tuned engine configs.

Winners of a tuning run are keyed by ``(graph signature, plan canon
sequence, device kind)`` — the graph half and template half of the engine
cache key plus the hardware the measurements were taken on — so a cached
config is only ever applied to the exact workload it was measured for, and
a checkout moved between machines re-tunes instead of trusting stale
numbers.

File anatomy (``version`` checked on load; mismatches are ignored with a
warning, never an error)::

    {
      "version": 1,
      "entries": {
        "<sig>|<canons-digest>|<device>": {
          "config": {... TuningConfig.to_json() ...},
          "meta":   {"measured_us": ..., "predicted_us": ..., ...}
        }
      },
      "calibration": {"edges": 1.07, "sell": 0.83, ...}
    }

``calibration`` carries the measured/predicted per-backend cost ratios the
tuner observed (the generalization of the PR 5 fusion-slack mechanism):
:func:`repro.plan.cost.load_backend_calibration` folds them back into the
candidate lattice so *predictions* improve machine-by-machine even for
workloads never tuned directly.

Reads are memoized by ``(path, mtime, size)`` — the hot path
(:func:`consult`, called from backend resolution on every engine cache-key
computation) costs one ``os.stat`` when the file is unchanged.  Corrupt
files, stale versions, and malformed entries all degrade to "no tuned
config" with one logged warning; they never raise into an engine build.
"""

from __future__ import annotations

import hashlib
import json
import logging
import os
import tempfile
from typing import Dict, Optional, Tuple

from .config import TUNING_SCHEMA_VERSION, TuningConfig

__all__ = [
    "TuningCache",
    "TUNE_CACHE_ENV_VAR",
    "default_cache_path",
    "canons_digest",
    "entry_key",
    "device_kind",
    "consult",
    "load_calibration",
    "invalidate_entry",
]

logger = logging.getLogger("repro.tune")

#: Environment override for the cache file path (default: repo-root
#: ``TUNED_counting.json``, next to the committed bench file).
TUNE_CACHE_ENV_VAR = "REPRO_TUNE_CACHE"

#: memoized parsed caches keyed by path -> (stat fingerprint, TuningCache).
_LOAD_CACHE: Dict[str, Tuple[Optional[Tuple[int, int]], "TuningCache"]] = {}

#: paths already warned about (corrupt / version mismatch) — warn once.
_WARNED: set = set()


def default_cache_path() -> str:
    env = os.environ.get(TUNE_CACHE_ENV_VAR, "").strip()
    if env:
        return env
    # src/repro/tune/cache.py -> repo root (mirrors cost._default_bench_path)
    root = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    )
    return os.path.join(root, "TUNED_counting.json")


def canons_digest(canons) -> str:
    """Stable digest of a plan's template-set canon sequence (the schedule
    identity — see ``TemplatePlan.canons``)."""
    return hashlib.sha1(repr(tuple(map(tuple, canons))).encode()).hexdigest()


def device_kind() -> str:
    """The hardware key measurements are valid for (``cpu``/``gpu``/``tpu``)."""
    import jax

    return str(jax.default_backend())


def entry_key(graph_signature: str, canons, device: Optional[str] = None) -> str:
    return "|".join(
        (str(graph_signature), canons_digest(canons), device or device_kind())
    )


class TuningCache:
    """In-memory view of one cache file; load/modify/save explicitly.

    Thread-compatibility note: instances are plain dict holders — the
    serving layer mutates them only from its single scheduler thread.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path if path is not None else default_cache_path()
        self.entries: Dict[str, Dict] = {}
        self.calibration: Dict[str, float] = {}

    # -- persistence ---------------------------------------------------------

    @classmethod
    def load(cls, path: Optional[str] = None) -> "TuningCache":
        """Parse the file at ``path`` (default-resolved).  A missing file
        yields an empty cache; a corrupt or version-mismatched file yields
        an empty cache with ONE warning — never an exception."""
        cache = cls(path)
        resolved = cache.path
        try:
            with open(resolved) as fh:
                data = json.load(fh)
        except FileNotFoundError:
            return cache
        except (OSError, json.JSONDecodeError, ValueError) as exc:
            _warn_once(resolved, f"unreadable tuning cache ({exc}) — ignoring it")
            return cache
        if not isinstance(data, dict):
            _warn_once(resolved, "tuning cache is not a JSON object — ignoring it")
            return cache
        version = data.get("version")
        if version != TUNING_SCHEMA_VERSION:
            _warn_once(
                resolved,
                f"tuning cache version {version!r} != supported "
                f"{TUNING_SCHEMA_VERSION} — ignoring it (re-tune to refresh)",
            )
            return cache
        entries = data.get("entries", {})
        if isinstance(entries, dict):
            cache.entries = {
                str(k): v for k, v in entries.items() if isinstance(v, dict)
            }
        calib = data.get("calibration", {})
        if isinstance(calib, dict):
            out = {}
            for name, ratio in calib.items():
                try:
                    ratio = float(ratio)
                except (TypeError, ValueError):
                    continue
                if ratio > 0:
                    out[str(name)] = ratio
            cache.calibration = out
        return cache

    def save(self, path: Optional[str] = None) -> str:
        """Atomic write (tmp + rename); returns the path written.  Also
        refreshes the read memo so a consult right after a save sees the
        new entries without waiting for an mtime tick."""
        target = path if path is not None else self.path
        payload = {
            "version": TUNING_SCHEMA_VERSION,
            "entries": self.entries,
            "calibration": self.calibration,
        }
        d = os.path.dirname(os.path.abspath(target)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".tuned-", dir=d)
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(payload, fh, indent=2, sort_keys=True)
            os.replace(tmp, target)
        finally:
            if os.path.exists(tmp):  # pragma: no cover - error path
                os.unlink(tmp)
        _LOAD_CACHE[target] = (_fingerprint(target), self)
        return target

    # -- entry access --------------------------------------------------------

    def get(
        self, graph_signature: str, canons, device: Optional[str] = None
    ) -> Optional[TuningConfig]:
        entry = self.entries.get(entry_key(graph_signature, canons, device))
        if entry is None:
            return None
        try:
            return TuningConfig.from_json(entry.get("config"))
        except (ValueError, TypeError, KeyError) as exc:
            _warn_once(
                self.path, f"malformed tuned entry ({exc}) — ignoring it"
            )
            return None

    def meta(
        self, graph_signature: str, canons, device: Optional[str] = None
    ) -> Optional[Dict]:
        entry = self.entries.get(entry_key(graph_signature, canons, device))
        return None if entry is None else dict(entry.get("meta", {}))

    def put(
        self,
        graph_signature: str,
        canons,
        config: TuningConfig,
        *,
        device: Optional[str] = None,
        meta: Optional[Dict] = None,
    ) -> str:
        key = entry_key(graph_signature, canons, device)
        self.entries[key] = {"config": config.to_json(), "meta": dict(meta or {})}
        return key

    def invalidate(
        self, graph_signature: str, canons, device: Optional[str] = None
    ) -> bool:
        return (
            self.entries.pop(entry_key(graph_signature, canons, device), None)
            is not None
        )

    def merge_calibration(self, ratios: Dict[str, float]) -> None:
        """Fold a tuning run's per-backend measured/predicted ratios in
        (newest run wins per backend — ratios are already medians)."""
        for name, ratio in ratios.items():
            if ratio > 0:
                self.calibration[str(name)] = float(ratio)


# ---------------------------------------------------------------------------
# Memoized read-side helpers (the engine-resolution hot path)
# ---------------------------------------------------------------------------


def _fingerprint(path: str) -> Optional[Tuple[int, int]]:
    try:
        st = os.stat(path)
        return (st.st_mtime_ns, st.st_size)
    except OSError:
        return None


def _load_memoized(path: Optional[str]) -> "TuningCache":
    resolved = path if path is not None else default_cache_path()
    fp = _fingerprint(resolved)
    hit = _LOAD_CACHE.get(resolved)
    if hit is not None and hit[0] == fp:
        return hit[1]
    cache = TuningCache.load(resolved)
    _LOAD_CACHE[resolved] = (fp, cache)
    return cache


def consult(
    graph_signature: str,
    canons,
    *,
    device: Optional[str] = None,
    path: Optional[str] = None,
) -> Optional[TuningConfig]:
    """The read path backend resolution uses: tuned config or ``None``.

    One ``os.stat`` when the file is unchanged; never raises (any failure
    degrades to ``None`` so an engine build falls through to the analytic
    heuristic)."""
    try:
        return _load_memoized(path).get(graph_signature, canons, device)
    except Exception as exc:  # pragma: no cover - defensive
        logger.debug("tuning cache consult failed: %s", exc)
        return None


def load_calibration(path: Optional[str] = None) -> Dict[str, float]:
    """The persisted per-backend measured/predicted cost ratios (empty dict
    when the cache is missing/corrupt — the lattice then runs uncalibrated)."""
    try:
        return dict(_load_memoized(path).calibration)
    except Exception:  # pragma: no cover - defensive
        return {}


def invalidate_entry(
    graph_signature: str,
    canons,
    *,
    device: Optional[str] = None,
    path: Optional[str] = None,
) -> bool:
    """Load-modify-save removal of one tuned entry (the quarantine path:
    a key failing deterministically must not be re-picked from the cache).
    Returns True when an entry was actually removed."""
    cache = _load_memoized(path)
    if not cache.invalidate(graph_signature, canons, device):
        return False
    cache.save()
    logger.info(
        "tuned entry invalidated for graph %s on %s (quarantine/interop)",
        str(graph_signature)[:12],
        device or device_kind(),
    )
    return True


def _warn_once(path: str, message: str) -> None:
    if path not in _WARNED:
        _WARNED.add(path)
        logger.warning("%s: %s", path, message)
