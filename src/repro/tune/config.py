"""TuningConfig: the value a tuning run produces and an engine consumes.

A :class:`TuningConfig` pins every knob the autotuner searches over — the
per-exec-group backend binding (possibly *mixed*: different backends for
different stages of one plan), the fused-slice ``column_batch``, and the
coloring ``chunk_size``.  It is a pure, frozen, JSON-round-trippable value
object with **no imports from the plan/cost/exec layers**, so the cache
module, the cost model's candidate lattice, and the engine can all pass it
around without import cycles.

Group bindings are addressed by the plan's exec-group *leader* — the
``(plan_idx, sub_idx)`` stage address that
:attr:`repro.plan.ir.TemplatePlan.exec_groups` keys groups by — because
that is the address the local executor dispatches on.  Binding addresses
are only meaningful against the plan the config was tuned for; the tuning
cache therefore keys entries by the plan's canon sequence (see
:mod:`repro.tune.cache`), so a config can never be applied to a plan with a
different schedule.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["TuningConfig", "TUNING_SCHEMA_VERSION"]

#: Schema version of the persisted cache file AND of serialized configs.
#: Bump on any incompatible layout change — loaders ignore (with a warning)
#: files or entries written under a different version.
#: v2: added ``memory_budget_bytes`` (the tuner sweeps the chunk picker's
#: budget) and ``mesh_comm`` (blocking | pipelined mesh collectives).
TUNING_SCHEMA_VERSION = 2


@dataclass(frozen=True)
class TuningConfig:
    """One tuned engine configuration (immutable, hashable).

    Attributes:
      default_backend: local backend name for every exec group without an
        explicit binding — and for bag ops and plain ``spmm`` calls, which
        are never group-bound.
      group_backends: sorted ``((plan_idx, sub_idx), backend)`` pairs
        binding specific exec-group leaders to specific backends.  Empty
        for a uniform (single-backend) config.
      column_batch: fused-slice width, or ``None`` to keep the engine's
        auto-pick.
      chunk_size: colorings per launch, or ``None`` to keep the picker's.
      memory_budget_bytes: the chunk picker's live-footprint budget this
        config was tuned under, or ``None`` for the caller's/default
        budget.  Folded into :meth:`key_fragment` so differently-budgeted
        engines never share compiled programs.
      mesh_comm: the mesh backend's collective scheme (``"blocking"`` |
        ``"pipelined"``), or ``None`` to keep the cost model's per-stage
        decision.  Meaningless (and ignored) on local backends.
    """

    default_backend: str
    group_backends: Tuple[Tuple[Tuple[int, int], str], ...] = ()
    column_batch: Optional[int] = None
    chunk_size: Optional[int] = None
    memory_budget_bytes: Optional[int] = None
    mesh_comm: Optional[str] = None
    version: int = field(default=TUNING_SCHEMA_VERSION)

    def __post_init__(self):
        # normalize: bindings sorted by address, redundant (== default)
        # bindings kept — they are meaningful ("this group was measured"),
        # but order must be canonical for key_fragment()/JSON stability
        object.__setattr__(
            self,
            "group_backends",
            tuple(
                sorted(
                    ((int(p), int(i)), str(b))
                    for (p, i), b in self.group_backends
                )
            ),
        )

    # -- derived views -------------------------------------------------------

    @property
    def mixed(self) -> bool:
        """True when any group is bound to a non-default backend."""
        return any(b != self.default_backend for _, b in self.group_backends)

    @property
    def backend_name(self) -> str:
        """The engine-level backend name this config resolves to:
        ``"mixed"`` when bindings disagree, else the uniform backend."""
        return "mixed" if self.mixed else self.default_backend

    def bindings(self) -> Dict[Tuple[int, int], str]:
        """Leader address -> backend name (executor dispatch form)."""
        return {addr: b for addr, b in self.group_backends}

    def key_fragment(self) -> Tuple:
        """The hashable fragment :func:`repro.core.engine.engine_cache_key`
        appends for a tuned engine — two engines tuned differently must
        never share compiled programs.  New fields append at the END so
        positional consumers of earlier elements keep their offsets."""
        return (
            "tuned",
            self.default_backend,
            self.group_backends,
            None if self.column_batch is None else int(self.column_batch),
            None if self.chunk_size is None else int(self.chunk_size),
            None
            if self.memory_budget_bytes is None
            else int(self.memory_budget_bytes),
            self.mesh_comm,
        )

    def describe(self) -> Dict:
        """JSON-safe summary for ``engine.describe()`` / service stats."""
        return {
            "backend": self.backend_name,
            "default_backend": self.default_backend,
            "groups": {f"{p}:{i}": b for (p, i), b in self.group_backends},
            "column_batch": self.column_batch,
            "chunk_size": self.chunk_size,
            "memory_budget_bytes": self.memory_budget_bytes,
            "mesh_comm": self.mesh_comm,
        }

    # -- JSON round trip (bit-exact: ints and strings only) ------------------

    def to_json(self) -> Dict:
        return {
            "version": int(self.version),
            "default_backend": self.default_backend,
            "group_backends": [
                [[p, i], b] for (p, i), b in self.group_backends
            ],
            "column_batch": self.column_batch,
            "chunk_size": self.chunk_size,
            "memory_budget_bytes": self.memory_budget_bytes,
            "mesh_comm": self.mesh_comm,
        }

    @staticmethod
    def from_json(data: Dict) -> "TuningConfig":
        """Inverse of :meth:`to_json`; raises ``ValueError`` on malformed
        or version-mismatched input (callers turn that into a warning)."""
        if not isinstance(data, dict):
            raise ValueError(f"TuningConfig JSON must be an object, got {type(data)}")
        version = data.get("version")
        if version != TUNING_SCHEMA_VERSION:
            raise ValueError(
                f"TuningConfig version {version!r} != supported "
                f"{TUNING_SCHEMA_VERSION}"
            )
        default = data.get("default_backend")
        if not isinstance(default, str) or not default:
            raise ValueError(f"bad default_backend {default!r}")
        raw_groups = data.get("group_backends", [])
        groups = []
        for entry in raw_groups:
            (p, i), b = entry  # malformed shapes raise here
            groups.append(((int(p), int(i)), str(b)))
        cb = data.get("column_batch")
        chunk = data.get("chunk_size")
        budget = data.get("memory_budget_bytes")
        mesh_comm = data.get("mesh_comm")
        if mesh_comm is not None and mesh_comm not in ("blocking", "pipelined"):
            raise ValueError(f"bad mesh_comm {mesh_comm!r}")
        return TuningConfig(
            default_backend=default,
            group_backends=tuple(groups),
            column_batch=None if cb is None else int(cb),
            chunk_size=None if chunk is None else int(chunk),
            memory_budget_bytes=None if budget is None else int(budget),
            mesh_comm=mesh_comm,
        )
