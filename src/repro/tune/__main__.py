"""Autotuner CLI: ``python -m repro.tune [templates...] [--graph SPEC]``.

Tunes one ``(graph, template set)`` pair on this device, prints the
measured-vs-predicted table for every probed candidate, and persists the
winner (plus per-backend calibration ratios) in the tuning cache — the
file a ``CountingService`` running with ``REPRO_TUNE=cached`` (the
default) picks up on its next engine build for the same workload.

Examples::

    python -m repro.tune                        # rmat2k u5-1, the bench pair
    python -m repro.tune u7 --graph rmat:8192:65536:7
    python -m repro.tune u5-1 u6 --top-n 8 --probes 9
    REPRO_TUNE_CACHE=/tmp/t.json python -m repro.tune --graph er:1000:8000

Graph specs: ``rmat:N:E[:SEED]``, ``er:N:E[:SEED]``, ``grid:R:C``.
"""

from __future__ import annotations

import argparse
import logging
import sys

from repro.core.graph import erdos_renyi_graph, grid_graph, rmat_graph
from repro.core.templates import get_template

from .cache import default_cache_path
from .search import DEFAULT_PROBES, DEFAULT_TOP_N, tune


def _parse_graph(spec: str):
    parts = spec.split(":")
    kind = parts[0]
    try:
        if kind == "rmat":
            n, e = int(parts[1]), int(parts[2])
            seed = int(parts[3]) if len(parts) > 3 else 0
            return rmat_graph(n, e, seed=seed), f"rmat(n={n}, edges={e}, seed={seed})"
        if kind == "er":
            n, e = int(parts[1]), int(parts[2])
            seed = int(parts[3]) if len(parts) > 3 else 0
            return (
                erdos_renyi_graph(n, e, seed=seed),
                f"erdos-renyi(n={n}, edges={e}, seed={seed})",
            )
        if kind == "grid":
            r, c = int(parts[1]), int(parts[2])
            return grid_graph(r, c), f"grid({r}x{c})"
    except (IndexError, ValueError) as exc:
        raise SystemExit(f"bad --graph spec {spec!r}: {exc}")
    raise SystemExit(f"unknown graph kind {kind!r} (rmat | er | grid)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="measurement-driven autotuning for the counting engine",
    )
    ap.add_argument(
        "templates",
        nargs="*",
        default=["u5-1"],
        help="template names tuned as one set (default: u5-1)",
    )
    ap.add_argument(
        "--graph",
        default="rmat:2048:20000:1",
        help="graph spec rmat:N:E[:SEED] | er:N:E[:SEED] | grid:R:C "
        "(default: the rmat2k bench graph)",
    )
    ap.add_argument(
        "--top-n",
        type=int,
        default=DEFAULT_TOP_N,
        help=f"predicted-best candidates to measure (default {DEFAULT_TOP_N})",
    )
    ap.add_argument(
        "--probes",
        type=int,
        default=DEFAULT_PROBES,
        help=f"timed launches per candidate (default {DEFAULT_PROBES})",
    )
    ap.add_argument(
        "--dtype", default="fp32", choices=["fp32", "bf16"], help="dtype policy"
    )
    ap.add_argument(
        "--cache",
        default=None,
        help="cache file to write (default: REPRO_TUNE_CACHE or repo-root "
        "TUNED_counting.json)",
    )
    ap.add_argument(
        "--dry-run", action="store_true", help="measure but do not persist"
    )
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    logging.basicConfig(
        level=logging.DEBUG if args.verbose else logging.WARNING,
        format="%(name)s %(levelname)s %(message)s",
    )
    graph, graph_desc = _parse_graph(args.graph)
    templates = [get_template(name) for name in args.templates]
    print(f"tuning [{', '.join(t.name for t in templates)}] on {graph_desc}")
    result = tune(
        graph,
        templates,
        top_n=args.top_n,
        probes=args.probes,
        dtype_policy=args.dtype,
        cache_path=args.cache,
        save=not args.dry_run,
    )
    print(
        f"device={result.device}  lattice={result.lattice_size} candidates, "
        f"measured top {len(result.measured)}  "
        f"(heuristic would pick: {result.heuristic_backend})"
    )
    print(f"{'backend':>8s} {'cb':>4s} {'chunk':>5s} "
          f"{'predicted':>12s} {'measured':>12s} {'miss':>7s}")
    for m in result.measured:
        marker = "  <- winner" if m.config == result.config else ""
        miss = (
            m.measured_us / m.predicted_us if m.predicted_us > 0 else float("inf")
        )
        print(
            f"{m.config.backend_name:>8s} {str(m.config.column_batch):>4s} "
            f"{str(m.config.chunk_size):>5s} {m.predicted_us:>10.1f}us "
            f"{m.measured_us:>10.1f}us {miss:>6.2f}x{marker}"
        )
    if result.config.mixed:
        print("winner group bindings:")
        for (p, i), b in result.config.group_backends:
            print(f"  stage {p}:{i} -> {b}")
    if result.calibration:
        calib = ", ".join(
            f"{k}={v:.3f}" for k, v in sorted(result.calibration.items())
        )
        print(f"per-backend calibration (measured/raw-predicted): {calib}")
    if result.cache_path:
        print(f"persisted -> {result.cache_path}")
    else:
        print(f"dry run: NOT persisted (would write {args.cache or default_cache_path()})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
