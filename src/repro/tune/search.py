"""The measurement-driven search: prune by prediction, decide by stopwatch.

Protocol (see ``docs/tuning.md``):

1. :meth:`repro.plan.cost.CostModel.candidate_lattice` ranks the config
   space (backends x column batches x chunk sizes + greedy mixed configs)
   by calibrated predicted cost — the analytic model's job is *pruning*;
2. only the top-N predicted candidates are ever compiled: each binds a
   probe :class:`~repro.core.engine.CountingEngine` and is measured with
   one warmup ``count_keys_chunk`` launch (compile + cache) followed by
   ``probes`` timed launches, scored by the **median** us-per-coloring;
3. the winner (min measured; ties break to the better-predicted, then the
   lattice order — same inputs, same winner, bit-for-bit) is persisted in
   the :class:`~repro.tune.cache.TuningCache` under
   ``(graph signature, plan canons, device kind)``, and every *uniform*
   candidate's measured/raw-predicted ratio is folded into the cache's
   per-backend ``calibration`` map (the fusion-slack mechanism,
   generalized to time).

Uniform probe engines pass their backend **explicitly** — explicit beats
the ``REPRO_ENGINE_BACKEND`` env override in the resolution ladder, so a
set env var cannot poison the measurements it is supposed to be able to
overrule at serve time.

``measure_fn`` is injectable so tests can replay canned measurements and
assert the search is a pure function of them.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .cache import TuningCache, canons_digest, device_kind, entry_key
from .config import TuningConfig

__all__ = ["tune", "TuneResult", "MeasuredCandidate", "measure_engine_us"]

logger = logging.getLogger("repro.tune")

#: Default number of predicted-best candidates that get compiled/measured.
DEFAULT_TOP_N = 5

#: Default timed launches per candidate (after one untimed warmup).
DEFAULT_PROBES = 5


@dataclass(frozen=True)
class MeasuredCandidate:
    """One probed lattice point: the config, both predictions, the clock."""

    config: TuningConfig
    predicted_us: float  # calibrated (what the ranking used)
    raw_us: float  # uncalibrated (what the new ratio is computed against)
    measured_us: float  # median us per coloring over the timed launches


@dataclass(frozen=True)
class TuneResult:
    """Everything one tuning run decided and observed."""

    config: TuningConfig  # the winner
    measured: Tuple[MeasuredCandidate, ...]  # probe order (lattice rank)
    calibration: Dict[str, float]  # per-backend measured/raw ratios, this run
    graph_signature: str
    canons_digest: str
    device: str
    cache_path: Optional[str]  # where the winner was persisted (None: not saved)
    lattice_size: int  # candidates ranked (measured = top-N of these)
    heuristic_backend: str  # what the analytic ladder would have picked
    meta: Dict = field(default_factory=dict)

    @property
    def winner(self) -> MeasuredCandidate:
        for m in self.measured:
            if m.config == self.config:
                return m
        raise LookupError("winner not in measured set")  # pragma: no cover


def measure_engine_us(engine, probes: int) -> float:
    """Median wall-clock microseconds **per coloring** over ``probes``
    chunk launches (one untimed warmup launch pays compile + operand
    transfer first).

    ``count_keys_chunk`` is the serving increment — probe launches share
    its padded single-compiled-shape contract, so what the tuner times is
    exactly what the service replays.
    """
    import jax

    keys = jax.random.split(jax.random.PRNGKey(0), engine.chunk_size)
    engine.count_keys_chunk(keys)  # warmup: compile + constant folding
    samples = []
    for _ in range(max(1, int(probes))):
        t0 = time.perf_counter()
        engine.count_keys_chunk(keys)  # returns a host array: synchronous
        samples.append(time.perf_counter() - t0)
    samples.sort()
    median_s = samples[len(samples) // 2]
    return median_s * 1e6 / max(1, engine.chunk_size)


def _geomean(vals: Sequence[float]) -> float:
    import math

    logs = [math.log(v) for v in vals if v > 0]
    return math.exp(sum(logs) / len(logs)) if logs else 1.0


def tune(
    graph,
    templates,
    *,
    top_n: int = DEFAULT_TOP_N,
    probes: int = DEFAULT_PROBES,
    dtype_policy="fp32",
    memory_budget_bytes: Optional[int] = None,
    platform: Optional[str] = None,
    cache_path: Optional[str] = None,
    save: bool = True,
    measure_fn: Optional[Callable] = None,
    interpret: bool = False,
    mesh=None,
) -> TuneResult:
    """Tune one ``(graph, template set)`` pair on this device.

    Builds the ranked candidate lattice, measures its ``top_n`` entries
    (``probes`` timed launches each), persists the winner + per-backend
    calibration in the tuning cache (unless ``save=False``), and returns
    the full :class:`TuneResult`.

    The lattice sweeps ``memory_budget_bytes`` (the given budget and its
    half) as an axis — each candidate's probe engine runs under the
    budget it was priced at, and the winner carries it in its
    ``key_fragment()``.  With ``mesh=`` (a ``jax.sharding.Mesh``), mesh
    candidates join the lattice with the collective mode (blocking |
    pipelined) as a further axis; their probe engines bind the mesh.

    Deterministic by construction: with a fixed ``measure_fn`` (or
    identical measurements) the same inputs produce the identical
    :class:`TuningConfig` — candidate order is the lattice's deterministic
    ranking and ties break toward it.
    """
    import jax.numpy as jnp

    from repro.core.engine import CountingEngine, DtypePolicy
    from repro.exec.select import heuristic_backend
    from repro.plan.cost import (
        DEFAULT_MEMORY_BUDGET_BYTES,
        CostModel,
        load_backend_calibration,
    )
    from repro.plan.ir import build_template_plan

    if measure_fn is None:
        measure_fn = measure_engine_us
    budget = (
        DEFAULT_MEMORY_BUDGET_BYTES
        if memory_budget_bytes is None
        else int(memory_budget_bytes)
    )
    templates = list(templates)
    plan = build_template_plan(templates)
    policy = DtypePolicy.resolve(dtype_policy)
    cost = CostModel(plan, graph, policy.store_dtype)
    calibration = load_backend_calibration(cache_path)
    mesh_shards = None
    if mesh is not None:
        import numpy as np

        mesh_shards = int(np.prod(mesh.devices.shape))
    lattice = cost.candidate_lattice(
        platform=platform,
        calibration=calibration,
        memory_budget_bytes=budget,
        mesh_shards=mesh_shards,
    )
    if not lattice:  # pragma: no cover - lattice always has >= 1 backend
        raise RuntimeError("empty candidate lattice")
    heur_name, _ = heuristic_backend(graph, platform)
    sig = graph.signature()
    probed = lattice[: max(1, int(top_n))]
    logger.info(
        "tuning %d templates on n=%d graph: measuring top %d of %d candidates "
        "(%d probes each)",
        len(templates),
        graph.n,
        len(probed),
        len(lattice),
        probes,
    )
    measured: List[MeasuredCandidate] = []
    for rank, cand in enumerate(probed):
        cfg = cand.config
        # explicit backend=: stronger than the env override, so a set
        # REPRO_ENGINE_BACKEND cannot poison the probe measurements
        engine = CountingEngine(
            graph,
            templates,
            backend=cfg.backend_name,
            tuning=cfg if cfg.backend_name == "mixed" else None,
            dtype_policy=policy,
            chunk_size=cfg.chunk_size,
            column_batch=cfg.column_batch,
            memory_budget_bytes=cfg.memory_budget_bytes or budget,
            interpret=interpret,
            mesh=mesh if cfg.backend_name == "mesh" else None,
            mesh_comm=cfg.mesh_comm if cfg.backend_name == "mesh" else None,
        )
        us = float(measure_fn(engine, probes))
        measured.append(
            MeasuredCandidate(
                config=cfg,
                predicted_us=cand.predicted_us,
                raw_us=cand.raw_us,
                measured_us=us,
            )
        )
        logger.info(
            "  [%d/%d] %-6s cb=%s chunk=%s predicted=%.1fus measured=%.1fus",
            rank + 1,
            len(probed),
            cfg.backend_name,
            cfg.column_batch,
            cfg.chunk_size,
            cand.predicted_us,
            us,
        )
    # winner: min measured; ties break to the prediction, then lattice rank
    win_idx = min(
        range(len(measured)),
        key=lambda i: (measured[i].measured_us, measured[i].predicted_us, i),
    )
    winner = measured[win_idx]
    # per-backend calibration from the UNIFORM candidates (a mixed config's
    # time cannot be attributed to one backend) against raw predictions
    ratios: Dict[str, List[float]] = {}
    for m in measured:
        if not m.config.mixed and m.raw_us > 0:
            ratios.setdefault(m.config.default_backend, []).append(
                m.measured_us / m.raw_us
            )
    run_calibration = {name: _geomean(vals) for name, vals in ratios.items()}
    device = device_kind()
    meta = {
        "measured_us": winner.measured_us,
        "predicted_us": winner.predicted_us,
        "heuristic_backend": heur_name,
        "probes": int(probes),
        "top_n": len(probed),
        "lattice_size": len(lattice),
        "templates": [t.name for t in templates],
        "dtype_policy": str(jnp.dtype(policy.store_dtype)),
    }
    path = None
    if save:
        cache = TuningCache.load(cache_path)
        cache.put(sig, plan.canons, winner.config, device=device, meta=meta)
        cache.merge_calibration(run_calibration)
        path = cache.save()
        logger.info(
            "tuned config persisted: %s -> %s (%s)",
            entry_key(sig, plan.canons, device),
            winner.config.describe(),
            path,
        )
    return TuneResult(
        config=winner.config,
        measured=tuple(measured),
        calibration=run_calibration,
        graph_signature=sig,
        canons_digest=canons_digest(plan.canons),
        device=device,
        cache_path=path,
        lattice_size=len(lattice),
        heuristic_backend=heur_name,
        meta=meta,
    )
