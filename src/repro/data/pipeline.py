"""Deterministic synthetic data pipelines (tokens, graphs, clicks).

Every iterator is a pure function of (seed, step) so a restarted job resumes
the exact stream position — required for bit-exact checkpoint/restart.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig, LMConfig, RecsysConfig
from repro.models.gnn.message import GraphBatch

__all__ = ["token_batches", "click_batches", "graph_batch_from_shape", "synthetic_cora"]


def token_batches(cfg: LMConfig, batch: int, seq_len: int, seed: int = 0, start_step: int = 0) -> Iterator:
    """Zipf-ish synthetic token stream: (tokens, labels) per step."""
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        # skewed unigram distribution ~ real text token frequencies
        u = rng.random((batch, seq_len + 1))
        toks = np.minimum((u ** -0.7 - 1.0) * 20, cfg.vocab_size - 1).astype(np.int32)
        yield jnp.asarray(toks[:, :-1]), jnp.asarray(toks[:, 1:])
        step += 1


def click_batches(cfg: RecsysConfig, batch: int, seed: int = 0, start_step: int = 0) -> Iterator:
    """(user_idx, item_idx, log_q) triples with power-law item popularity."""
    step = start_step
    n_uf, n_if, bag = cfg.n_user_fields, cfg.n_item_fields, cfg.multi_hot_per_field
    while True:
        rng = np.random.default_rng((seed, step))
        u = rng.random((batch, n_uf, bag))
        i = rng.random((batch, n_if, bag))
        user_idx = np.stack(
            [np.minimum((u[:, f] ** 2) * v, v - 1).astype(np.int32) for f, v in enumerate(cfg.user_vocab_sizes[:n_uf])],
            axis=1,
        )
        item_idx = np.stack(
            [np.minimum((i[:, f] ** 2) * v, v - 1).astype(np.int32) for f, v in enumerate(cfg.item_vocab_sizes[:n_if])],
            axis=1,
        )
        log_q = np.log(1.0 / (1.0 + item_idx[:, 0, 0].astype(np.float64) + 1e-6)).astype(np.float32)
        yield jnp.asarray(user_idx), jnp.asarray(item_idx), jnp.asarray(log_q)
        step += 1


def synthetic_cora(n: int = 2708, e: int = 5278, d: int = 1433, classes: int = 7, seed: int = 0):
    """Cora-shaped citation graph: features, labels, and a Graph."""
    from repro.core.graph import erdos_renyi_graph

    g = erdos_renyi_graph(n, e, seed=seed)
    rng = np.random.default_rng(seed)
    feat = (rng.random((n, d)) < 0.012).astype(np.float32)  # sparse bag-of-words
    labels = rng.integers(0, classes, size=n).astype(np.int32)
    return g, feat, labels


def graph_batch_from_shape(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    seed: int = 0,
    batch_graphs: int = 1,
    with_positions: bool = True,
) -> Tuple[GraphBatch, jnp.ndarray]:
    """Device-ready GraphBatch (+int labels) for a shape cell; block-diagonal
    when ``batch_graphs > 1`` (molecule cells)."""
    rng = np.random.default_rng(seed)
    n_total = n_nodes * batch_graphs
    e_total = n_edges * batch_graphs
    src = rng.integers(0, n_nodes, size=e_total).astype(np.int32)
    dst = rng.integers(0, n_nodes, size=e_total).astype(np.int32)
    offs = np.repeat(np.arange(batch_graphs, dtype=np.int32) * n_nodes, n_edges)
    src, dst = src + offs, dst + offs
    batch = GraphBatch(
        node_feat=jnp.asarray(rng.standard_normal((n_total, d_feat)).astype(np.float32)),
        positions=jnp.asarray(rng.standard_normal((n_total, 3)).astype(np.float32) * 2.0)
        if with_positions
        else None,
        src=jnp.asarray(src),
        dst=jnp.asarray(dst),
        edge_mask=jnp.ones((e_total,), jnp.float32),
        node_mask=jnp.ones((n_total,), jnp.float32),
        graph_id=jnp.asarray(np.repeat(np.arange(batch_graphs, dtype=np.int32), n_nodes)),
        n_graphs=batch_graphs,
    )
    labels = jnp.asarray(rng.integers(0, 7, size=n_total).astype(np.int32))
    return batch, labels
